"""End-to-end LM training driver with SpreadFGL gossip across simulated pods.

  PYTHONPATH=src python examples/train_lm_gossip.py --steps 200

Trains a ~125M-parameter xLSTM (the paper's aggregation technique lifted to
LM training, DESIGN.md §3) on 4 simulated pods: each pod takes local steps on
its batch shard; every K steps parameters ring-gossip (Eq. 16) instead of
all-reducing. Compares the loss trajectory against classic all-reduce data
parallelism on the same token stream.

NOTE: this script re-execs itself with XLA_FLAGS to create 4 host devices.
"""
import argparse
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data.lm_data import token_batches
from repro.optim.adam import Adam
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gossip-every", type=int, default=4)
    ap.add_argument("--variant", default="full", choices=("full", "smoke"))
    args = ap.parse_args()

    cfg = configs.get_config("xlstm-125m", args.variant,
                             scan_layers=False, remat=False)
    pods = len(jax.devices())
    mesh = jax.make_mesh((pods,), ("pod",))
    opt = Adam(lr=3e-4, clip_norm=1.0)

    n_params = None
    results = {}
    for mode in ("allreduce", "spread"):
        state = init_state(jax.random.key(0), cfg, opt)
        if n_params is None:
            n_params = sum(int(np.prod(p.shape))
                           for p in jax.tree.leaves(state.params))
            print(f"[example] xlstm-125m ({args.variant}): "
                  f"{n_params/1e6:.1f}M params on {pods} simulated pods")
        inner = make_train_step(cfg, opt, aggregation=mode,
                                gossip_every=args.gossip_every,
                                pod_axis="pod" if mode == "spread" else None)

        if mode == "spread":
            def per_pod(state_blk, batch_blk):
                st = jax.tree.map(lambda t: t[0], state_blk)
                st, metrics = inner(st, batch_blk)
                return jax.tree.map(lambda t: t[None], st), metrics
            step = jax.jit(shard_map(per_pod, mesh=mesh,
                                     in_specs=(P("pod"), P("pod")),
                                     out_specs=(P("pod"), P("pod")),
                                     check_rep=False))
            state = jax.tree.map(
                lambda t: jnp.broadcast_to(t, (pods,) + t.shape).copy(), state)
        else:
            def allreduce_pod(state_blk, batch_blk):
                from repro.core import gossip
                st = jax.tree.map(lambda t: t[0], state_blk)
                st, metrics = inner(st, batch_blk)
                st = st._replace(params=gossip.all_average(st.params, "pod"))
                return jax.tree.map(lambda t: t[None], st), metrics
            step = jax.jit(shard_map(allreduce_pod, mesh=mesh,
                                     in_specs=(P("pod"), P("pod")),
                                     out_specs=(P("pod"), P("pod")),
                                     check_rep=False))
            state = jax.tree.map(
                lambda t: jnp.broadcast_to(t, (pods,) + t.shape).copy(), state)

        data = token_batches(cfg, batch=args.batch, seq_len=args.seq, seed=42)
        losses = []
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            state, metrics = step(state, batch)
            losses.append(float(jnp.mean(metrics["loss"])))
            if i % 20 == 0 or i == args.steps - 1:
                print(f"[{mode:9s}] step {i:4d} loss {losses[-1]:.4f}")
        results[mode] = losses

    a, s = results["allreduce"][-10:], results["spread"][-10:]
    print(f"\nfinal-10 mean loss: allreduce={np.mean(a):.4f} "
          f"spread={np.mean(s):.4f}")
    print("gossip exchanges 2 neighbor copies every "
          f"{args.gossip_every} steps vs a full all-reduce every step: "
          f"{2 / args.gossip_every / (2 * (pods - 1) / pods):.2f}x relative "
          "cross-pod traffic (see EXPERIMENTS.md §Perf)")


if __name__ == "__main__":
    main()
