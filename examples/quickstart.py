"""Quickstart: FedGL on a synthetic Cora stand-in, 10 communication rounds.

  PYTHONPATH=src python examples/quickstart.py

Trains the paper's full pipeline (GraphSAGE clients + graph imputation
generator + versatile assessor + negative sampling) on one edge server and
prints accuracy per round — a 2-minute CPU demonstration of the public
``init / step / fit`` lifecycle.
"""
import jax

from repro.core import registry
from repro.core.partition import count_missing_links, partition_graph
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph


def main():
    # 1. Data: SBM stand-in for Cora (offline container), split across 6
    #    clients with all cross-client links DELETED (the missing links).
    graph = make_sbm_graph(DATASETS["cora"], scale=0.15, seed=1,
                           feature_noise=3.0, signal_ratio=0.5)
    batch, assign = partition_graph(graph, num_clients=6, aug_max=12, seed=0,
                                    label_ratio=0.3)
    print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
          f"{graph.num_classes} classes")
    print(f"deleted cross-client links: {count_missing_links(graph, assign)}")

    # 2. FedGL (Sec. III-B): one edge server, imputation every K=2 rounds.
    #    Every named method is a strategy composition in the registry.
    #    kernel_impl picks the hot-path kernels: "reference" (jnp) here;
    #    "pallas" routes classifier aggregation AND the imputation round's
    #    similarity top-k through the fused Pallas kernels on TPU
    #    ("pallas_interpret" runs the same kernels on CPU).
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=12, kernel_impl="reference")
    trainer = registry.build("FedGL", cfg, batch)

    # 3. Drive Algorithm 1 round by round: init -> step -> step -> ...
    #    step() returns metrics as device arrays; we sync each round here
    #    because we print each round (fit() below syncs only once).
    state = trainer.init(jax.random.key(0), batch)
    best = 0.0
    for _ in range(4):
        state, m = trainer.step(state)
        best = max(best, float(m["acc"]))
        print(f"round {m['round']:2d}  loss={float(m['loss']):7.4f}  "
              f"acc={float(m['acc']):.3f}  f1={float(m['f1']):.3f}")

    # 4. fit() is the same loop, picking up exactly where `state` stopped.
    state, hist = trainer.fit(state=state, rounds=6)
    for i, r in enumerate(hist["round"]):
        print(f"round {r:2d}  loss={hist['loss'][i]:7.4f}  "
              f"acc={hist['acc'][i]:.3f}  f1={hist['f1'][i]:.3f}")
    print(f"best accuracy: {max([best] + hist['acc']):.3f}")


if __name__ == "__main__":
    main()
