"""Quickstart: FedGL on a synthetic Cora stand-in, 10 communication rounds.

  PYTHONPATH=src python examples/quickstart.py

Trains the paper's full pipeline (GraphSAGE clients + graph imputation
generator + versatile assessor + negative sampling) on one edge server and
prints accuracy per round — a 2-minute CPU demonstration of the public API.
"""
import jax

from repro.core.partition import count_missing_links, partition_graph
from repro.core.spreadfgl import make_fedgl
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph


def main():
    # 1. Data: SBM stand-in for Cora (offline container), split across 6
    #    clients with all cross-client links DELETED (the missing links).
    graph = make_sbm_graph(DATASETS["cora"], scale=0.15, seed=1,
                           feature_noise=3.0, signal_ratio=0.5)
    batch, assign = partition_graph(graph, num_clients=6, aug_max=12, seed=0,
                                    label_ratio=0.3)
    print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
          f"{graph.num_classes} classes")
    print(f"deleted cross-client links: {count_missing_links(graph, assign)}")

    # 2. FedGL (Sec. III-B): one edge server, imputation every K=2 rounds.
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=12)
    trainer = make_fedgl(cfg, batch)

    # 3. Train (Algorithm 1) and report.
    state, hist = trainer.fit(jax.random.key(0), batch, rounds=10)
    for r, (loss, acc, f1) in enumerate(zip(hist["loss"], hist["acc"],
                                            hist["f1"])):
        print(f"round {r:2d}  loss={loss:7.4f}  acc={acc:.3f}  f1={f1:.3f}")
    print(f"best accuracy: {max(hist['acc']):.3f}")


if __name__ == "__main__":
    main()
