"""Batched serving example: prefill + greedy decode with KV/recurrent caches.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b

Serves a reduced-config model: batches 4 prompts, prefills them in one shot,
then decodes 24 tokens per request. Works for every assigned architecture
(GQA KV caches, MoE experts, mamba/mLSTM recurrent states, whisper/VLM
cross-attention memory).
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.data.lm_data import memory_stub
from repro.models import transformer
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, "smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.steps + 8)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    memory = memory_stub(cfg, args.batch)
    print(f"[serve] {cfg.name}: {args.batch} requests × "
          f"{args.prompt_len} prompt tokens -> {args.steps} new tokens")
    out = engine.generate(prompts, steps=args.steps,
                          temperature=args.temperature, memory=memory)
    for i, row in enumerate(out):
        print(f"  request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
