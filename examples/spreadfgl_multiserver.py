"""SpreadFGL vs FedGL vs baselines: the paper's multi-edge scenario.

  PYTHONPATH=src python examples/spreadfgl_multiserver.py [--impl pallas]

Three edge servers on a ring (the paper's testbed topology), Eq. 16 neighbor
aggregation + Eq. 15 trace regularizer, compared against the centralized
FedGL, the decentralized gossip variant (``spreadfgl_gossip``, cross-server
exchange every ``--gossip-every`` rounds only), and the three baselines of
Sec. IV-A on the same partition. ``--impl`` selects the hot-path kernels
(reference | pallas | pallas_interpret) for every method — the single
``FGLConfig.kernel_impl`` knob covers both classifier aggregation and the
imputation round's fused similarity top-k.

The heterogeneity axis rides along: ``--partitioner dirichlet --alpha 0.1``
skews the client split non-IID and ``--participation 0.5`` lets only half
the clients aggregate per round (see ``docs/BENCHMARKS.md``, heterogeneity
section, for the full sweep).
"""
import argparse

import jax

from repro.core import registry
from repro.core.partition import (PARTITIONERS, label_skew_entropy,
                                  make_partitioner, partition_graph)
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph
from repro.launch.mesh import make_edge_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="reference",
                    choices=("reference", "pallas", "pallas_interpret"))
    ap.add_argument("--gossip-every", type=int, default=4,
                    help="cross-server exchange interval of the gossip row")
    ap.add_argument("--partitioner", default="label_prop",
                    choices=tuple(sorted(PARTITIONERS)),
                    help="client-split strategy (heterogeneity axis)")
    ap.add_argument("--alpha", type=float, default=1.0,
                    help="Dirichlet concentration (--partitioner dirichlet)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-round participating-client fraction rho")
    args = ap.parse_args()

    graph = make_sbm_graph(DATASETS["citeseer"], scale=0.15, seed=1,
                           feature_noise=3.0, signal_ratio=0.5)
    part = make_partitioner(args.partitioner, alpha=args.alpha)
    batch, assign = partition_graph(graph, num_clients=6, aug_max=12, seed=0,
                                    partitioner=part)
    ent = label_skew_entropy(assign, graph.y, 6)
    print(f"partitioner={args.partitioner} rho={args.participation} "
          f"mean client label entropy={ent.mean():.3f} nats")
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=12, kernel_impl=args.impl,
                    participation=args.participation)

    # The [N] server axis shards across whatever devices exist (size-1 mesh on
    # a single-device host — identical numbers, no sharding). Every method is
    # a registered strategy composition.
    mesh = make_edge_mesh(3)
    methods = {
        "LocalFGL": registry.build("local", cfg, batch),
        "FedAvg-fusion": registry.build("fedavg_fusion", cfg, batch),
        "FedSage+": registry.build("fedsage_plus", cfg, batch),
        "FedGL": registry.build("FedGL", cfg, batch),
        "SpreadFGL (3 servers, ring)": registry.build(
            "SpreadFGL", cfg, batch, num_servers=3, edge_mesh=mesh),
        f"SpreadFGL-gossip (K={args.gossip_every})": registry.build(
            "spreadfgl_gossip", cfg, batch, num_servers=3,
            gossip_every=args.gossip_every, edge_mesh=mesh),
    }
    print(f"{'method':30s} {'best ACC':>9s} {'best F1':>9s} {'final loss':>11s}")
    for name, tr in methods.items():
        _, hist = tr.fit(jax.random.key(0), batch, rounds=12)
        print(f"{name:30s} {max(hist['acc']):9.3f} {max(hist['f1']):9.3f} "
              f"{hist['loss'][-1]:11.4f}")


if __name__ == "__main__":
    main()
