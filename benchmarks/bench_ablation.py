"""Fig. 7: ablation of negative sampling (NS) and versatile assessor (Assor),
M=6, labeled ratio 0.3."""
from __future__ import annotations

from benchmarks.common import fgl_setup, run_method, write_result


VARIANTS = {
    "FedAvg-fusion (baseline)": ("FedAvg-fusion", {}),
    "FedGL w/o NS+Assor": ("FedGL", dict(use_negative_sampling=False,
                                         use_assessor=False)),
    "FedGL w/o NS": ("FedGL", dict(use_negative_sampling=False)),
    "FedGL w/o Assor": ("FedGL", dict(use_assessor=False)),
    "FedGL (full)": ("FedGL", {}),
    "SpreadFGL (full)": ("SpreadFGL", {}),
}


def main(fast: bool = False):
    print("[bench] Fig. 7 — ablation (NS / Assor)")
    rounds = 8 if fast else 12
    out = {}
    _, batch, cfg = fgl_setup("cora", 6)
    for label, (method, kw) in VARIANTS.items():
        hist = run_method(method, cfg, batch, rounds=rounds, **kw)
        out[label] = {"acc": max(hist["acc"]), "f1": max(hist["f1"])}
        print(f"  {label:28s} ACC={out[label]['acc']:.3f}", flush=True)
    write_result("fig7_ablation", out)
    return out


if __name__ == "__main__":
    main()
