"""Benchmark orchestrator — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Emits a ``name,us_per_call,derived`` CSV summary at the end (per-benchmark
wall time + headline derived metric), and writes JSON details under
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (bench_ablation, bench_accuracy, bench_convergence,
                        bench_heterogeneity, bench_k_sensitivity,
                        bench_kernels, bench_load_balance, bench_roofline,
                        bench_sim_scaling)

BENCHES = {
    "table2_accuracy": bench_accuracy.main,
    "fig7_ablation": bench_ablation.main,
    "fig8_convergence": bench_convergence.main,
    "fig5_k_sensitivity": bench_k_sensitivity.main,
    "heterogeneity": bench_heterogeneity.main,
    "load_balance": bench_load_balance.main,
    "kernels": bench_kernels.main,
    "roofline": bench_roofline.main,
    "sim_scaling": bench_sim_scaling.main,
}


def _headline(name: str, result) -> str:
    try:
        if name == "table2_accuracy":
            spread = [v["acc"] for k, v in result.items() if "SpreadFGL" in k]
            local = [v["acc"] for k, v in result.items() if "LocalFGL" in k]
            return (f"spread_acc={sum(spread)/len(spread):.3f};"
                    f"local_acc={sum(local)/len(local):.3f}")
        if name == "fig7_ablation":
            return (f"full={result['FedGL (full)']['acc']:.3f};"
                    f"base={result['FedAvg-fusion (baseline)']['acc']:.3f}")
        if name == "fig8_convergence":
            auls = {k.split("/")[-1]: v["area_under_loss"]
                    for k, v in result.items() if k.startswith("cora")}
            return (f"aul_spread={auls.get('SpreadFGL', 0):.2f};"
                    f"aul_fedavg={auls.get('FedAvg-fusion', 0):.2f}")
        if name == "fig5_k_sensitivity":
            return ";".join(f"K{k}={v['acc']:.3f}" for k, v in result["K"].items())
        if name == "heterogeneity":
            s = result["summary"]
            return (f"spread_acc={s['spread_acc']:.3f};"
                    f"local_acc={s['local_acc']:.3f}")
        if name == "load_balance":
            return f"peak_load_reduction={result['peak_load_reduction']:.2f}x"
        if name == "kernels":
            return f"n_kernels={len(result)}"
        if name == "roofline":
            return (f"ok={result.get('ok', 0)};skipped={result.get('skipped', 0)};"
                    f"failed={result.get('failed', 0)}")
        if name == "sim_scaling":
            top = max(result["rows"], key=lambda r: r["n"])
            return (f"n_max={top['n']};"
                    f"gflops={top['achieved_flops_per_s']/1e9:.1f}")
    except Exception as e:  # noqa: BLE001
        return f"headline_error={e!r}"
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced rounds/datasets (CI-sized)")
    ap.add_argument("--only", choices=tuple(BENCHES), action="append",
                    help="run only these benchmarks (repeatable)")
    args = ap.parse_args()

    rows = []
    for name, fn in BENCHES.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        result = fn(fast=args.fast)
        dt = (time.time() - t0) * 1e6
        rows.append((name, dt, _headline(name, result)))

    print("\nname,us_per_call,derived")
    for name, dt, derived in rows:
        print(f"{name},{dt:.0f},{derived}")


if __name__ == "__main__":
    main()
