"""Heterogeneity x partial-participation sweep (the regime that stresses FGL).

The paper evaluates one homogeneous scenario: label-propagation partitioning
with every client aggregating every round. Related work (AdaFGL's topology
heterogeneity, FedGTA's non-IID subgraphs) shows the interesting regime is
skewed partitions and partial participation — this bench opens that axis:

    alpha in {100, 1, 0.1}   Dirichlet label-skew concentration (IID -> skewed)
    rho   in {1.0, 0.5, 0.25}  participating-client fraction per round

for SpreadFGL (3 edge servers, ring) vs FedGL vs LocalFGL on the same
Dirichlet partition (``repro.core.partition.DirichletPartitioner``; the
participation mask is sampled per round inside the engine, see
``FGLConfig.participation``). The claim validated is the ORDERING: adaptive
neighbor generation (SpreadFGL/FedGL) recovers accuracy that purely local
training cannot, and the recovery persists — or matters more — as the split
skews and participation drops. Per-cell mean client label entropy (nats) is
recorded as the skew diagnostic.

Writes ``benchmarks/results/heterogeneity.json``; regenerate with
``PYTHONPATH=src python -m benchmarks.run --only heterogeneity``
(``--fast`` shrinks the sweep to one alpha x two rho for CI).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import fgl_setup, make_method, write_result
from repro.core.partition import (DirichletPartitioner, count_missing_links,
                                  label_skew_entropy)

import jax

ALPHAS = (100.0, 1.0, 0.1)
RHOS = (1.0, 0.5, 0.25)
METHODS = ("SpreadFGL", "FedGL", "LocalFGL")
CLIENTS = 6


def run(alphas, rhos, *, rounds=12, seeds=(1, 2), scale=0.2) -> dict:
    sweep, entropy = {}, {}
    for alpha in alphas:
        for seed in seeds:
            part = DirichletPartitioner(alpha=alpha)
            g, batch, cfg0 = fgl_setup("cora", CLIENTS, seed=seed, scale=scale,
                                       partitioner=part)
            # Same deterministic split fgl_setup materialized (partition
            # seed 0) — re-derived only for the skew diagnostics.
            assign = part.assign(g, CLIENTS, seed=0)
            ent = label_skew_entropy(assign, g.y, CLIENTS)
            entropy.setdefault(f"alpha={alpha:g}", []).append(float(ent.mean()))
            cut = count_missing_links(g, assign)
            for rho in rhos:
                cfg = dataclasses.replace(cfg0, participation=rho, seed=seed)
                for method in METHODS:
                    kw = {"num_servers": 3} if method == "SpreadFGL" else {}
                    tr = make_method(method, cfg, batch, **kw)
                    _, hist = tr.fit(jax.random.key(seed), batch, rounds=rounds)
                    cell = sweep.setdefault(
                        f"alpha={alpha:g}/rho={rho:g}/{method}",
                        {"acc": [], "f1": [], "missing_links": []})
                    cell["acc"].append(max(hist["acc"]))
                    cell["f1"].append(max(hist["f1"]))
                    cell["missing_links"].append(cut)
    for key, cell in sweep.items():
        cell["acc_std"] = float(np.std(cell["acc"]))
        cell["acc"] = float(np.mean(cell["acc"]))
        cell["f1"] = float(np.mean(cell["f1"]))
        cell["missing_links"] = float(np.mean(cell["missing_links"]))
        print(f"  {key:36s} ACC={cell['acc']:.3f}±{cell['acc_std']:.3f}",
              flush=True)

    # The headline ordering: neighbor generation vs purely local, per cell.
    ordering = {}
    for alpha in alphas:
        for rho in rhos:
            spread = sweep[f"alpha={alpha:g}/rho={rho:g}/SpreadFGL"]["acc"]
            local = sweep[f"alpha={alpha:g}/rho={rho:g}/LocalFGL"]["acc"]
            ordering[f"alpha={alpha:g}/rho={rho:g}"] = {
                "spread_minus_local": float(spread - local),
                "spread_beats_local": bool(spread >= local)}
    mean = lambda m: float(np.mean(  # noqa: E731
        [c["acc"] for k, c in sweep.items() if k.endswith("/" + m)]))
    payload = {
        "datasets": "cora (SBM stand-in)", "clients": CLIENTS,
        "rounds": rounds, "seeds": list(seeds), "scale": scale,
        "mean_client_label_entropy_nats": {
            k: float(np.mean(v)) for k, v in entropy.items()},
        "sweep": sweep, "ordering": ordering,
        "summary": {"spread_acc": mean("SpreadFGL"),
                    "fedgl_acc": mean("FedGL"),
                    "local_acc": mean("LocalFGL")},
    }
    write_result("heterogeneity", payload)
    return payload


def main(fast: bool = False):
    print("[bench] heterogeneity — Dirichlet label skew x partial participation")
    if fast:
        return run((1.0,), (1.0, 0.5), rounds=6, seeds=(1,), scale=0.12)
    return run(ALPHAS, RHOS)


if __name__ == "__main__":
    main()
