"""Heterogeneity x partial-participation sweep (the regime that stresses FGL).

The paper evaluates one homogeneous scenario: label-propagation partitioning
with every client aggregating every round. Related work (AdaFGL's topology
heterogeneity, FedGTA's non-IID subgraphs) shows the interesting regime is
skewed partitions and partial participation — this bench opens that axis:

    alpha in {100, 1, 0.1}   Dirichlet label-skew concentration (IID -> skewed)
    rho   in {1.0, 0.5, 0.25}  participating-client fraction per round

for SpreadFGL (3 edge servers, ring) vs FedGL vs LocalFGL on the same
Dirichlet partition (``repro.core.partition.DirichletPartitioner``; the
participation mask is sampled per round inside the engine, see
``FGLConfig.participation``). The claim validated is the ORDERING: adaptive
neighbor generation (SpreadFGL/FedGL) recovers accuracy that purely local
training cannot, and the recovery persists — or matters more — as the split
skews and participation drops. Per-cell mean client label entropy (nats) is
recorded as the skew diagnostic.

The second sweep (``run_async``) opens the STRAGGLER axis the paper's
Sec. III-E motivates: FedBuff-style buffered aggregation
(``repro.core.strategies.AsyncAggregator``) with

    B    in {2, 3, 6}          buffer size (6 = M = synchronous limit)
    rho  in {1.0, 0.5}          participating-client fraction per round
    delay in {zero, uniform, geometric}   arrival-delay distribution

recording full convergence histories per cell — the claim validated is that
buffered flushes with staleness discounting track the synchronous
convergence while no longer waiting on the slowest client (B = M with zero
delays IS the synchronous FedAvg-on-ring run, bit-identically; smaller B
trades staleness for liveness under delay/dropout).

Writes ``benchmarks/results/heterogeneity.json`` and
``benchmarks/results/heterogeneity_async.json``; regenerate with
``PYTHONPATH=src python -m benchmarks.run --only heterogeneity``
(``--fast`` shrinks both sweeps for CI and exercises the B axis).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import fgl_setup, make_method, write_result
from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.partition import (DirichletPartitioner, count_missing_links,
                                  label_skew_entropy)

import jax

ALPHAS = (100.0, 1.0, 0.1)
RHOS = (1.0, 0.5, 0.25)
METHODS = ("SpreadFGL", "FedGL", "LocalFGL")
CLIENTS = 6


def run(alphas, rhos, *, rounds=12, seeds=(1, 2), scale=0.2) -> dict:
    sweep, entropy = {}, {}
    for alpha in alphas:
        for seed in seeds:
            part = DirichletPartitioner(alpha=alpha)
            g, batch, cfg0 = fgl_setup("cora", CLIENTS, seed=seed, scale=scale,
                                       partitioner=part)
            # Same deterministic split fgl_setup materialized (partition
            # seed 0) — re-derived only for the skew diagnostics.
            assign = part.assign(g, CLIENTS, seed=0)
            ent = label_skew_entropy(assign, g.y, CLIENTS)
            entropy.setdefault(f"alpha={alpha:g}", []).append(float(ent.mean()))
            cut = count_missing_links(g, assign)
            for rho in rhos:
                cfg = dataclasses.replace(cfg0, participation=rho, seed=seed)
                for method in METHODS:
                    kw = {"num_servers": 3} if method == "SpreadFGL" else {}
                    tr = make_method(method, cfg, batch, **kw)
                    _, hist = tr.fit(jax.random.key(seed), batch, rounds=rounds)
                    cell = sweep.setdefault(
                        f"alpha={alpha:g}/rho={rho:g}/{method}",
                        {"acc": [], "f1": [], "missing_links": []})
                    cell["acc"].append(max(hist["acc"]))
                    cell["f1"].append(max(hist["f1"]))
                    cell["missing_links"].append(cut)
    for key, cell in sweep.items():
        cell["acc_std"] = float(np.std(cell["acc"]))
        cell["acc"] = float(np.mean(cell["acc"]))
        cell["f1"] = float(np.mean(cell["f1"]))
        cell["missing_links"] = float(np.mean(cell["missing_links"]))
        print(f"  {key:36s} ACC={cell['acc']:.3f}±{cell['acc_std']:.3f}",
              flush=True)

    # The headline ordering: neighbor generation vs purely local, per cell.
    ordering = {}
    for alpha in alphas:
        for rho in rhos:
            spread = sweep[f"alpha={alpha:g}/rho={rho:g}/SpreadFGL"]["acc"]
            local = sweep[f"alpha={alpha:g}/rho={rho:g}/LocalFGL"]["acc"]
            ordering[f"alpha={alpha:g}/rho={rho:g}"] = {
                "spread_minus_local": float(spread - local),
                "spread_beats_local": bool(spread >= local)}
    mean = lambda m: float(np.mean(  # noqa: E731
        [c["acc"] for k, c in sweep.items() if k.endswith("/" + m)]))
    payload = {
        "datasets": "cora (SBM stand-in)", "clients": CLIENTS,
        "rounds": rounds, "seeds": list(seeds), "scale": scale,
        "mean_client_label_entropy_nats": {
            k: float(np.mean(v)) for k, v in entropy.items()},
        "sweep": sweep, "ordering": ordering,
        "summary": {"spread_acc": mean("SpreadFGL"),
                    "fedgl_acc": mean("FedGL"),
                    "local_acc": mean("LocalFGL")},
    }
    write_result("heterogeneity", payload)
    return payload


BUFFERS = (2, 3, 6)          # 6 == M == the synchronous limit
ASYNC_RHOS = (1.0, 0.5)
DELAY_DISTS = ("zero", "uniform", "geometric")


def run_async(buffers, rhos, delay_dists, *, rounds=12, seeds=(1, 2),
              scale=0.2, dropout=0.1) -> dict:
    """B x rho x delay-distribution sweep of the buffered async aggregator."""
    sweep = {}
    for seed in seeds:
        g, batch, cfg0 = fgl_setup("cora", CLIENTS, seed=seed, scale=scale)
        # The synchronous convergence reference every async cell is compared
        # against (the paper's method: dense Eq. 16 mixing on the ring).
        cfg_sync = dataclasses.replace(cfg0, seed=seed)
        tr = make_method("SpreadFGL", cfg_sync, batch)
        _, hist_sync = tr.fit(jax.random.key(seed), batch, rounds=rounds)
        sweep.setdefault("sync/SpreadFGL", {"acc": [], "history": []})
        sweep["sync/SpreadFGL"]["acc"].append(max(hist_sync["acc"]))
        sweep["sync/SpreadFGL"]["history"].append(hist_sync["acc"])
        # The bit-identity anchor target: the async aggregator's zero-delay
        # B = M limit is per-server FedAvg on the same ring — NOT dense
        # Eq. 16 (which mixes across servers every round) — so the anchor
        # compares against a FedAvg-on-ring composition, mirroring
        # tests/test_async_agg.py at benchmark scale.
        tr = FGLTrainer(cfg_sync, batch, topology=S.RingTopology(3),
                        aggregator=S.FedAvgAggregator(),
                        imputation=S.SpreadImputation())
        _, hist_ref = tr.fit(jax.random.key(seed), batch, rounds=rounds)
        sweep.setdefault("sync/FedAvg-ring", {"acc": [], "history": []})
        sweep["sync/FedAvg-ring"]["acc"].append(max(hist_ref["acc"]))
        sweep["sync/FedAvg-ring"]["history"].append(hist_ref["acc"])
        for dist in delay_dists:
            for rho in rhos:
                for b in buffers:
                    drop = 0.0 if dist == "zero" else dropout
                    cfg = dataclasses.replace(
                        cfg0, participation=rho, seed=seed, async_buffer=b,
                        delay_dist=dist, dropout_rate=drop)
                    tr = make_method("SpreadFGL-async", cfg, batch)
                    _, hist = tr.fit(jax.random.key(seed), batch,
                                     rounds=rounds)
                    cell = sweep.setdefault(
                        f"delay={dist}/rho={rho:g}/B={b}",
                        {"acc": [], "history": []})
                    cell["acc"].append(max(hist["acc"]))
                    cell["history"].append(hist["acc"])
    for key, cell in sweep.items():
        cell["acc_std"] = float(np.std(cell["acc"]))
        cell["acc"] = float(np.mean(cell["acc"]))
        print(f"  {key:36s} ACC={cell['acc']:.3f}±{cell['acc_std']:.3f}",
              flush=True)

    # The correctness anchor, asserted in the committed artifact: B = M with
    # zero delays IS the synchronous FedAvg-on-ring run (every flush has
    # weights all 1, which reduces to the plain per-server mean) — exactly,
    # not just allclose.
    anchors = {}
    if CLIENTS in buffers and "zero" in delay_dists and 1.0 in rhos:
        a = sweep[f"delay=zero/rho=1/B={CLIENTS}"]
        anchors["b_equals_m_zero_delay_matches_sync_fedavg_ring"] = bool(
            np.array_equal(a["history"], sweep["sync/FedAvg-ring"]["history"]))
    payload = {
        "datasets": "cora (SBM stand-in)", "clients": CLIENTS,
        "rounds": rounds, "seeds": list(seeds), "scale": scale,
        "buffers": list(buffers), "rhos": list(rhos),
        "delay_dists": list(delay_dists), "dropout_rate": dropout,
        "staleness_weighting": "1/sqrt(1+tau)",
        "sweep": sweep, "anchors": anchors,
    }
    write_result("heterogeneity_async", payload)
    return payload


def main(fast: bool = False):
    print("[bench] heterogeneity — Dirichlet label skew x partial participation")
    if fast:
        out = run((1.0,), (1.0, 0.5), rounds=6, seeds=(1,), scale=0.12)
        print("[bench] heterogeneity — async straggler axis (B x rho x delay)")
        run_async((2, CLIENTS), (1.0,), ("zero", "geometric"), rounds=6,
                  seeds=(1,), scale=0.12)
        return out
    out = run(ALPHAS, RHOS)
    print("[bench] heterogeneity — async straggler axis (B x rho x delay)")
    run_async(BUFFERS, ASYNC_RHOS, DELAY_DISTS)
    return out


if __name__ == "__main__":
    main()
