"""Scaling the imputation similarity search: candidate-sharded ring top-k
at n ∈ {10k, 100k, 1M} synthetic nodes.

The question this bench answers: does ``core/ring_topk.py`` make the A̅ =
H Hᵀ similarity sweep (Sec. III-C) — the FGL-side compute wall — scale to
the ROADMAP's million-node regime? For each n it:

- Generates an SBM graph in the scale-up regime of
  ``data/synthetic_graphs.py`` (``scale > 1.0``, vectorized sampler) and
  builds class-probability embeddings H [n, c] from its labels — the same
  kind of softmax-space features the generator round fuses.
- Times the ring-sharded masked top-k of ``q`` query rows against ALL n
  candidates (full-sweep timing at n = 1M is ~2e13 FLOPs — days on host
  CPU — so the sweep is query-subsampled and the full-sweep time is
  reported as the measured-rate extrapolation, labeled as such).
- Validates achieved FLOP/s against the ``repro.roofline`` peak
  (``hw.PEAK_FLOPS_BF16``) — achieved must stay below peak, and the
  fraction is reported — and accounts per-rotation / total ring bytes next
  to the all-gather alternative (byte model in ``core/ring_topk.py``,
  conventions shared with ``core/gossip.py``), plus the per-device
  candidate residency that makes the sharded layout fit at 1M nodes.
- Asserts ring == single-device parity on the smallest n before timing
  anything (the strict bit-identical contract lives in
  ``tests/test_ring_topk.py``; this is the bench's own smoke seal).

Run standalone it emulates 8 host devices (flag handled before the first
jax import, same idiom as ``bench_load_balance``); under ``benchmarks.run``
it uses whatever devices exist (a 1-device host degenerates to the unsharded
fold — byte accounting then reports zero cross-device traffic).

``--fast`` caps n at 10k (CI-sized). Results:
``benchmarks/results/sim_scaling.json``.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit, write_result
from repro.core.ring_topk import (allgather_bytes, ring_rotation_bytes,
                                  ring_similarity_topk, ring_total_bytes,
                                  sim_topk_flops)
from repro.data.synthetic_graphs import DatasetStats, make_sbm_graph
from repro.roofline import hw

C = 16            # embedding width (softmax-space class dim, Table-I sized)
K = 8             # top-k links kept per query row
N_CLIENTS = 8     # client id stripes for the cross-subgraph mask


def _embeddings(n: int, seed: int):
    """H [n, C] from a scale-up SBM graph: softmax(class one-hot + noise).

    The graph comes from the documented ``scale > 1.0`` generator path
    (num_nodes = n/2 at scale 2.0), so this bench exercises exactly the
    regime ``tests/test_synthetic_scale.py`` pins.
    """
    stats = DatasetStats("sim_scaling", n // 2, n // 2, 32, C, 0.7)
    g = make_sbm_graph(stats, scale=2.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    logits = (2.0 * np.eye(C, dtype=np.float32)[g.y]
              + rng.standard_normal((n, C)).astype(np.float32))
    h = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    cid = jnp.asarray(np.arange(n) % N_CLIENTS, jnp.int32)
    tmask = jnp.asarray(rng.random(n) < 0.9, jnp.float32)
    return h, cid, tmask


def _bench_one(n: int, q: int, mesh, iters: int):
    size = int(mesh.size)
    h, cid, tmask = _embeddings(n, seed=n % 1000)
    queries, qcid = h[:q], cid[:q]

    fn = jax.jit(lambda h_, c_, t_, q_, qc_: ring_similarity_topk(
        h_, c_, t_, K, mesh=mesh, queries=q_, query_cid=qc_))
    us = timeit(lambda: fn(h, cid, tmask, queries, qcid),
                warmup=1, iters=iters)
    secs = us / 1e6

    flops = sim_topk_flops(q, n, C)
    achieved = flops / secs
    peak = hw.PEAK_FLOPS_BF16
    assert achieved < peak, (
        f"measured {achieved:.3e} FLOP/s exceeds the roofline peak "
        f"{peak:.3e} — the FLOP model or the timer is wrong")
    row = {
        "n": n, "q": q, "c": C, "k": K, "mesh_devices": size,
        "wall_us": us,
        "flops": flops,
        "achieved_flops_per_s": achieved,
        "peak_flops_per_s": peak,
        "fraction_of_peak": achieved / peak,
        "extrapolated_full_sweep_s": secs * (n / q),
        "bytes_per_rotation": ring_rotation_bytes(n, C, size),
        "ring_total_bytes": ring_total_bytes(n, C, size),
        "allgather_bytes": allgather_bytes(n, C, size),
        "rotation_ici_us": (ring_rotation_bytes(n, C, size)
                            / hw.ICI_BW_PER_LINK * 1e6),
        "candidate_bytes_per_device": float(
            ((n + size - 1) // size) * (C * 4 + 8)),
        "candidate_bytes_unsharded": float(n * (C * 4 + 8)),
    }
    print(f"  n={n:>9,} q={q} devices={size}: {us/1e3:9.1f} ms  "
          f"{achieved/1e9:8.2f} GFLOP/s ({row['fraction_of_peak']:.2e} of "
          f"peak)  rot={row['bytes_per_rotation']/1e6:.2f} MB  "
          f"full-sweep≈{row['extrapolated_full_sweep_s']:.1f}s")
    return row


def _parity_seal(mesh):
    """Ring == single-device reference on a small case before timing."""
    from repro.core import imputation
    h, cid, tmask = _embeddings(2000, seed=0)
    exp_s, exp_i = imputation.similarity_topk(h, jnp.ones(2000), cid, K,
                                              target_mask=tmask)
    got_s, got_i = imputation.similarity_topk(h, jnp.ones(2000), cid, K,
                                              target_mask=tmask, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(exp_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(exp_s))


def main(fast: bool = False):
    from jax.sharding import Mesh
    n_dev = len(jax.devices())
    print(f"[bench] sim scaling: candidate-sharded ring top-k on {n_dev} "
          f"device(s)")
    mesh = Mesh(np.array(jax.devices()), ("sim",))
    _parity_seal(mesh)
    print(f"  parity seal: ring(size={mesh.size}) == reference at n=2000")

    sizes = (2_000, 10_000) if fast else (10_000, 100_000, 1_000_000)
    q = 256 if fast else 1024
    iters = 2 if fast else 3
    out = {"devices": n_dev, "fast": bool(fast),
           "query_subsample_note":
               "wall_us times q query rows against all n candidates; "
               "extrapolated_full_sweep_s scales the measured rate to q=n",
           "rows": [_bench_one(n, min(q, n), mesh, iters) for n in sizes]}
    write_result("sim_scaling", out)
    return out


if __name__ == "__main__":
    main()
