"""Fig. 8/9: training-loss and accuracy curves per method (M=6, ratio 0.3).
Claim: FedGL/SpreadFGL converge faster (loss ↓, acc ↑ in fewer rounds)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import METHODS, fgl_setup, run_method, write_result


def main(fast: bool = False):
    print("[bench] Fig. 8/9 — convergence curves")
    rounds = 8 if fast else 16
    out = {}
    for ds in ("cora",) if fast else ("cora", "citeseer"):
        _, batch, cfg = fgl_setup(ds, 6)
        for method in METHODS:
            hist = run_method(method, cfg, batch, rounds=rounds)
            # area-under-loss as a scalar convergence-speed proxy
            aul = float(np.trapezoid(hist["loss"]))
            out[f"{ds}/{method}"] = {"loss": hist["loss"], "acc": hist["acc"],
                                     "area_under_loss": aul}
            print(f"  {ds}/{method:16s} AUL={aul:7.3f} "
                  f"final_loss={hist['loss'][-1]:.4f}", flush=True)
    write_result("fig8_convergence", out)
    return out


if __name__ == "__main__":
    main()
