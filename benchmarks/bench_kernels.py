"""Kernel micro-benchmarks: µs/call of the jnp reference path on CPU plus the
interpret-mode Pallas check (TPU wall-time is N/A in this container — the
kernel's TPU performance claim lives in the roofline analysis instead).

The sim-topology rows sweep ``kernel_impl``: the jnp reference
``similarity_topk`` (per-block gram + ``jax.lax.top_k`` over all n columns)
against the fused masked top-k kernel, at the shapes the imputation round
actually feeds (c = num classes ≤ 15, n in the thousands).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit, write_result
from repro.core import imputation
from repro.kernels import ops, ref


def main(fast: bool = False):
    print("[bench] kernels — µs/call (CPU reference path)")
    # Distinct keys per tensor: timing attention on q == k == v would measure
    # a degenerate (identical-operand) problem.
    kq, kk, kv, ka, kh, kr, km, ks = jax.random.split(jax.random.key(0), 8)
    rows = {}

    q = jax.random.normal(kq, (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(kk, (1, 8, 512, 64), jnp.float32)
    v = jax.random.normal(kv, (1, 8, 512, 64), jnp.float32)
    fn = jax.jit(lambda: ref.flash_attention(q, k, v, causal=True))
    rows["flash_attention_ref_512"] = timeit(fn)

    a = (jax.random.uniform(ka, (512, 512)) < 0.1).astype(jnp.float32)
    h = jax.random.normal(kh, (512, 256), jnp.float32)
    rows["sage_aggregate_ref_512"] = timeit(jax.jit(lambda: ref.sage_aggregate(a, h)))

    rowsm = jax.random.normal(kr, (256, 15), jnp.float32)
    hm = jax.random.normal(km, (4096, 15), jnp.float32)
    rows["sim_block_ref_4k"] = timeit(jax.jit(lambda: ref.sim_block(rowsm, hm)))

    # The imputation hot path end-to-end (gram + masks + top-k), both impls.
    n, c, topk = (1024, 10, 5) if fast else (4096, 10, 5)
    hs = jax.nn.softmax(jax.random.normal(ks, (n, c)), -1)
    mask = jnp.ones((n,))
    cid = imputation.client_of_flat(8, n // 8)
    rows[f"similarity_topk_reference_{n}"] = timeit(jax.jit(
        lambda: imputation.similarity_topk(hs, mask, cid, topk,
                                           kernel_impl="reference")))
    rows[f"sim_topk_fused_interpret_{n}"] = timeit(
        lambda: ops.sim_topk(hs, cid, mask, topk, interpret=True), iters=2)

    if not fast:
        rows["flash_attention_pallas_interpret_256"] = timeit(
            lambda: ops.mha(q[:, :, :256], k[:, :, :256], v[:, :, :256],
                            causal=True, interpret=True), iters=2)

    for k2, v2 in rows.items():
        print(f"  {k2:42s} {v2:12.1f} us")
    write_result("kernels_micro", rows)
    return rows


if __name__ == "__main__":
    main()
