"""Kernel micro-benchmarks: µs/call of the jnp reference path on CPU plus the
interpret-mode Pallas check (TPU wall-time is N/A in this container — the
kernel's TPU performance claim lives in the roofline analysis instead)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit, write_result
from repro.kernels import ops, ref


def main(fast: bool = False):
    print("[bench] kernels — µs/call (CPU reference path)")
    key = jax.random.key(0)
    rows = {}

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    v = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    fn = jax.jit(lambda: ref.flash_attention(q, k, v, causal=True))
    rows["flash_attention_ref_512"] = timeit(fn)

    a = (jax.random.uniform(key, (512, 512)) < 0.1).astype(jnp.float32)
    h = jax.random.normal(key, (512, 256), jnp.float32)
    rows["sage_aggregate_ref_512"] = timeit(jax.jit(lambda: ref.sage_aggregate(a, h)))

    rowsm = jax.random.normal(key, (256, 15), jnp.float32)
    hm = jax.random.normal(key, (4096, 15), jnp.float32)
    rows["sim_block_ref_4k"] = timeit(jax.jit(lambda: ref.sim_block(rowsm, hm)))

    if not fast:
        rows["flash_attention_pallas_interpret_256"] = timeit(
            lambda: ops.mha(q[:, :, :256], k[:, :, :256], v[:, :, :256],
                            causal=True, interpret=True), iters=2)

    for k2, v2 in rows.items():
        print(f"  {k2:42s} {v2:12.1f} us")
    write_result("kernels_micro", rows)
    return rows


if __name__ == "__main__":
    main()
