"""Edge-layer load balancing (Sec. III-E claim): per-server aggregation
traffic and peak load, FedGL (single edge server) vs SpreadFGL (N servers,
ring topology).

Bytes are computed from the actual classifier parameter tree: every
edge-client communication a server receives W from each covered client and
broadcasts back; on imputation rounds SpreadFGL servers additionally exchange
parameters with their ring neighbors (Eq. 16). The paper's claim: the maximum
per-server load drops ~N× — the single aggregation point disappears.

The wall-time section measures the stacked-[N] refactor: one vmapped
imputation round (sharded over the edge mesh when >1 device is available) vs
the seed's sequential per-server loop (``_imputation_round_reference``) for
N ∈ {1, 2, 4, 8} on the same host. Run as a script this emulates 8 host
devices so the mesh actually spreads servers; via ``run.py`` it uses whatever
devices exist.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from benchmarks.common import ROUNDS, fgl_setup, timeit, write_result
from repro.core import gossip
from repro.core.partition import ring_adjacency
from repro.core.spreadfgl import (make_fedgl, make_spreadfgl,
                                  make_spreadfgl_gossip)
from repro.launch.mesh import make_edge_mesh


def param_bytes(trainer, batch) -> int:
    state = trainer.init(jax.random.key(0), batch)
    one_client = jax.tree.map(lambda p: p[0], state.params)
    return int(sum(np.prod(p.shape) * p.dtype.itemsize
                   for p in jax.tree.leaves(one_client)))


def main(fast: bool = False):
    print("[bench] edge-layer load balance (FedGL vs SpreadFGL)")
    _, batch, cfg = fgl_setup("cora", 6)
    out = {}
    for name, make in (("FedGL(N=1)", lambda: make_fedgl(cfg, batch)),
                       ("SpreadFGL(N=3)", lambda: make_spreadfgl(cfg, batch,
                                                                 num_servers=3))):
        tr = make()
        pb = param_bytes(tr, batch)
        m_per = tr.m_per
        n = tr.n_servers
        # per round: up + down per covered client; + neighbor exchange on
        # K-rounds (byte math shared with core/gossip.py).
        per_round = 2 * m_per * pb
        neighbor = gossip.dense_neighbor_bytes_per_round(
            ring_adjacency(n), pb, every=cfg.imputation_interval)
        out[name] = {"servers": n, "clients_per_server": m_per,
                     "param_bytes": pb,
                     "per_server_bytes_per_round": per_round + neighbor,
                     "peak_load_bytes": per_round + neighbor}
        print(f"  {name:16s} per-server bytes/round = "
              f"{(per_round + neighbor)/1e6:.3f} MB (clients={m_per})")
    ratio = (out["FedGL(N=1)"]["peak_load_bytes"]
             / out["SpreadFGL(N=3)"]["peak_load_bytes"])
    out["peak_load_reduction"] = ratio
    print(f"  peak-load reduction: {ratio:.2f}x")
    out["imputation_walltime"] = bench_imputation_walltime(fast=fast)
    out["impl_sweep"] = bench_impl_sweep(fast=fast)
    out["gossip"] = bench_gossip_aggregation(fast=fast)
    write_result("load_balance", out)
    return out


def bench_gossip_aggregation(fast: bool = False):
    """Gossip-K vs dense Eq. 16 vs FedAvg: bytes/round, wall time, convergence.

    For N ∈ {1, 2, 4, 8} edge servers, reports per-server cross-server
    bytes/round (amortized over the exchange interval; math from
    ``core/gossip.py``) and the measured wall time of one aggregation call —
    on exchange rounds AND on skip rounds, where the gossip aggregator
    lowers to per-server FedAvg with zero cross-server collectives. A
    convergence sweep at a representative N records full accuracy/F1
    histories for gossip-K ∈ {1, 4, 8} against dense neighbor aggregation
    and single-point FedGL. Own results file:
    ``results/gossip_load_balance.json``.
    """
    n_dev = len(jax.devices())
    print(f"[bench] gossip aggregation (K-amortized exchange) on {n_dev} "
          f"device(s)")
    _, batch, cfg = fgl_setup("cora", 8)   # 8 clients: N in {1,2,4,8} divide
    iters = 2 if fast else 5
    ks = (1, 4, 8)
    out = {"devices": n_dev, "gossip_K": list(ks)}

    for n in ((1, 2) if fast else (1, 2, 4, 8)):
        mesh = make_edge_mesh(n) if (n > 1 and n_dev > 1) else None
        tr_d = (make_fedgl(cfg, batch) if n == 1
                else make_spreadfgl(cfg, batch, num_servers=n, edge_mesh=mesh))
        pb = param_bytes(tr_d, batch)
        out.setdefault("param_bytes", pb)
        adj = ring_adjacency(n)
        state_d = tr_d.init(jax.random.key(0), batch)
        rows = {"dense_neighbor": {
            "cross_server_bytes_per_round":
                gossip.dense_neighbor_bytes_per_round(adj, pb),
            "agg_round_us": timeit(
                lambda: tr_d.aggregate(state_d.params, round=0), iters=iters)},
            "fedavg_allreduce": {
            "cross_server_bytes_per_round":
                gossip.allreduce_bytes_per_round(pb, n)}}
        for k in ks:
            tr_g = make_spreadfgl_gossip(cfg, batch, num_servers=n,
                                         gossip_every=k, edge_mesh=mesh)
            state_g = tr_g.init(jax.random.key(0), batch)
            t_ex = timeit(lambda: tr_g.aggregate(state_g.params, round=k - 1),
                          iters=iters)
            t_skip = (t_ex if k == 1 else
                      timeit(lambda: tr_g.aggregate(state_g.params, round=0),
                             iters=iters))
            bytes_pr = (gossip.ring_gossip_bytes_per_round(pb, every=k)
                        if n >= 3 else
                        gossip.dense_neighbor_bytes_per_round(adj, pb, every=k))
            rows[f"gossip_K{k}"] = {
                "cross_server_bytes_per_round": bytes_pr,
                "exchange_round_us": t_ex, "skip_round_us": t_skip,
                "amortized_round_us": (t_ex + (k - 1) * t_skip) / k}
            print(f"  N={n} gossip K={k}: bytes/round {bytes_pr/1e3:8.2f} kB  "
                  f"exchange {t_ex/1e3:7.2f} ms  skip {t_skip/1e3:7.2f} ms")
        dense_b = rows["dense_neighbor"]["cross_server_bytes_per_round"]
        for k in ks:
            gb = rows[f"gossip_K{k}"]["cross_server_bytes_per_round"]
            rows[f"gossip_K{k}"]["bytes_vs_dense"] = (
                gb / dense_b if dense_b else 1.0)
        out[f"N={n}"] = rows

    # Convergence: does K-amortized exchange track dense aggregation?
    n_conv = 2 if fast else 4
    rounds = 4 if fast else ROUNDS
    conv = {"servers": n_conv, "rounds": rounds}
    mesh = make_edge_mesh(n_conv) if (n_conv > 1 and n_dev > 1) else None
    runs = [("FedGL", lambda: make_fedgl(cfg, batch)),
            ("dense_neighbor", lambda: make_spreadfgl(
                cfg, batch, num_servers=n_conv, edge_mesh=mesh))]
    runs += [(f"gossip_K{k}", lambda k=k: make_spreadfgl_gossip(
        cfg, batch, num_servers=n_conv, gossip_every=k, edge_mesh=mesh))
        for k in ks]
    for name, make in runs:
        _, hist = make().fit(jax.random.key(0), batch, rounds=rounds)
        conv[name] = hist
        print(f"  convergence N={n_conv} {name:14s} "
              f"best acc={max(hist['acc']):.3f} f1={max(hist['f1']):.3f}")
    out["convergence"] = conv
    write_result("gossip_load_balance", out)
    return out


def bench_impl_sweep(fast: bool = False):
    """kernel_impl sweep over the full imputation round (own results file).

    Times one vmapped SpreadFGL imputation round per impl. On CPU the Pallas
    path runs in interpret mode (``pallas_interpret``), so its wall time is a
    correctness checkpoint, not a speed claim — the compiled ``pallas`` row
    only appears when a TPU is attached.
    """
    print("[bench] kernel_impl sweep over the imputation round")
    _, batch, cfg = fgl_setup("cora", 6)
    on_tpu = jax.default_backend() == "tpu"
    impls = ("reference", "pallas") if on_tpu else ("reference",
                                                    "pallas_interpret")
    iters = 2 if fast else 5
    out = {"backend": jax.default_backend()}
    for impl in impls:
        tr = make_spreadfgl(cfg, batch, num_servers=3, kernel_impl=impl)
        state = tr.init(jax.random.key(0), batch)
        t = timeit(lambda: tr._impute_fn(state), iters=iters)
        out[impl] = {"imputation_round_us": t}
        print(f"  {impl:18s} imputation round {t/1e3:8.1f} ms")
    if "reference" in out and len(out) > 2:
        other = [i for i in impls if i != "reference"][0]
        out["speedup_vs_reference"] = (
            out["reference"]["imputation_round_us"]
            / out[other]["imputation_round_us"])
    write_result("impl_sweep", out)
    return out


def bench_imputation_walltime(fast: bool = False):
    """Per-round wall time of the imputation round, vmapped vs sequential."""
    n_dev = len(jax.devices())
    print(f"[bench] imputation round wall time (vmapped [N] on {n_dev} "
          f"device(s) vs sequential loop)")
    _, batch, cfg = fgl_setup("cora", 8)   # 8 clients: N in {1,2,4,8} all divide
    iters = 2 if fast else 5
    out = {"devices": n_dev}

    for n in ((1, 2) if fast else (1, 2, 4, 8)):
        mesh = make_edge_mesh(n) if (n > 1 and n_dev > 1) else None
        tr_v = (make_fedgl(cfg, batch) if n == 1
                else make_spreadfgl(cfg, batch, num_servers=n, edge_mesh=mesh))
        state_v = tr_v.init(jax.random.key(0), batch)
        t_vmap = timeit(lambda: tr_v._impute_fn(state_v), iters=iters)
        # Sequential baseline: the seed's per-server loop, single device.
        tr_s = (make_fedgl(cfg, batch) if n == 1
                else make_spreadfgl(cfg, batch, num_servers=n))
        state_s = tr_s.init(jax.random.key(0), batch)
        seq_fn = jax.jit(tr_s._imputation_round_reference)
        t_seq = timeit(lambda: seq_fn(state_s), iters=iters)
        out[f"N={n}"] = {"servers": n, "mesh_devices": mesh.size if mesh else 1,
                         "vmapped_round_us": t_vmap,
                         "sequential_round_us": t_seq,
                         "speedup": t_seq / t_vmap}
        print(f"  N={n}: vmapped {t_vmap/1e3:8.1f} ms "
              f"(mesh={mesh.size if mesh else 1})   "
              f"sequential {t_seq/1e3:8.1f} ms   speedup {t_seq/t_vmap:.2f}x")
    return out


if __name__ == "__main__":
    main()
