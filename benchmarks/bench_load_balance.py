"""Edge-layer load balancing (Sec. III-E claim): per-server aggregation
traffic and peak load, FedGL (single edge server) vs SpreadFGL (N servers,
ring topology).

Bytes are computed from the actual classifier parameter tree: every
edge-client communication a server receives W from each covered client and
broadcasts back; on imputation rounds SpreadFGL servers additionally exchange
parameters with their ring neighbors (Eq. 16). The paper's claim: the maximum
per-server load drops ~N× — the single aggregation point disappears.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fgl_setup, write_result
from repro.core.spreadfgl import make_fedgl, make_spreadfgl


def param_bytes(trainer, batch) -> int:
    state = trainer.init(jax.random.key(0), batch)
    one_client = jax.tree.map(lambda p: p[0], state.params)
    return int(sum(np.prod(p.shape) * p.dtype.itemsize
                   for p in jax.tree.leaves(one_client)))


def main(fast: bool = False):
    print("[bench] edge-layer load balance (FedGL vs SpreadFGL)")
    _, batch, cfg = fgl_setup("cora", 6)
    out = {}
    for name, make in (("FedGL(N=1)", lambda: make_fedgl(cfg, batch)),
                       ("SpreadFGL(N=3)", lambda: make_spreadfgl(cfg, batch,
                                                                 num_servers=3))):
        tr = make()
        pb = param_bytes(tr, batch)
        m_per = tr.m_per
        n = tr.n_servers
        # per round: up + down per covered client; + 2 neighbors on K-rounds
        per_round = 2 * m_per * pb
        neighbor = (2 * pb if n > 1 else 0) / cfg.imputation_interval
        out[name] = {"servers": n, "clients_per_server": m_per,
                     "param_bytes": pb,
                     "per_server_bytes_per_round": per_round + neighbor,
                     "peak_load_bytes": per_round + neighbor}
        print(f"  {name:16s} per-server bytes/round = "
              f"{(per_round + neighbor)/1e6:.3f} MB (clients={m_per})")
    ratio = (out["FedGL(N=1)"]["peak_load_bytes"]
             / out["SpreadFGL(N=3)"]["peak_load_bytes"])
    out["peak_load_reduction"] = ratio
    print(f"  peak-load reduction: {ratio:.2f}x")
    write_result("load_balance", out)
    return out


if __name__ == "__main__":
    main()
