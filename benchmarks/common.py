"""Shared helpers for the benchmark harness.

All FGL benchmarks run on reduced-scale synthetic stand-ins (see DESIGN.md §8)
with settings where the paper's *orderings* are reproducible on CPU in
minutes: feature_noise=3.0, signal_ratio=0.5 (features alone are insufficient,
neighbor structure carries class signal — the regime the paper targets).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.core import registry
from repro.core.partition import partition_graph
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph

RESULTS = pathlib.Path(__file__).parent / "results"

SCALE = 0.15
NOISE = 3.0
SIGNAL = 0.5
ROUNDS = 12


def fgl_setup(dataset: str, num_clients: int, *, seed: int = 1,
              label_ratio: float = 0.3, aug_max: int = 12, scale: float = None,
              partitioner=None, participation: float = 1.0):
    """Graph + partition + config for one benchmark cell.

    ``partitioner`` (a ``repro.core.partition.Partitioner`` or registry
    name) and ``participation`` open the heterogeneity axis; the defaults
    reproduce the homogeneous every-client setup of the paper benches.
    """
    g = make_sbm_graph(DATASETS[dataset], scale=scale or SCALE, seed=seed,
                       feature_noise=NOISE, signal_ratio=SIGNAL)
    batch, assign = partition_graph(g, num_clients, aug_max=aug_max,
                                    seed=0, label_ratio=label_ratio,
                                    partitioner=partitioner)
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=aug_max, label_ratio=label_ratio,
                    participation=participation)
    return g, batch, cfg


# Display name -> (registry name, extra kwargs); all methods resolve through
# repro.core.registry, the same compositions the launcher exposes.
_REGISTRY_NAMES = {
    "LocalFGL": ("local", {}),
    "FedAvg-fusion": ("fedavg_fusion", {}),
    "FedSage+": ("fedsage_plus", {}),
    "FedGL": ("FedGL", {}),
    "SpreadFGL": ("SpreadFGL", {"num_servers": 3}),
    "SpreadFGL-gossip": ("spreadfgl_gossip", {"num_servers": 3}),
    "SpreadFGL-async": ("spreadfgl_async", {"num_servers": 3}),
}


def make_method(name: str, cfg, batch, **kw):
    reg_name, extra = _REGISTRY_NAMES[name]
    return registry.build(reg_name, cfg, batch, **{**extra, **kw})


METHODS = ("LocalFGL", "FedAvg-fusion", "FedSage+", "FedGL", "SpreadFGL")


def run_method(name: str, cfg, batch, *, rounds: int = ROUNDS, seed: int = 0,
               **kw) -> Dict[str, list]:
    tr = make_method(name, cfg, batch, **kw)
    _, hist = tr.fit(jax.random.key(seed), batch, rounds=rounds)
    return hist


def write_result(name: str, payload) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1))
    return path


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
