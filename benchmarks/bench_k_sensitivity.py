"""Fig. 5/6: sensitivity to the imputation interval K and local rounds T_l."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import fgl_setup, make_method, write_result


def main(fast: bool = False):
    print("[bench] Fig. 5/6 — K and T_l sensitivity")
    out = {"K": {}, "Tl": {}}
    _, batch, cfg0 = fgl_setup("cora", 6)
    rounds = 8 if fast else 14
    ks = (1, 2, 6) if fast else (1, 2, 4, 8, 12)
    for k in ks:
        cfg = dataclasses.replace(cfg0, imputation_interval=k)
        tr = make_method("SpreadFGL", cfg, batch)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=rounds)
        out["K"][k] = {"acc": max(hist["acc"]), "f1": max(hist["f1"])}
        print(f"  K={k:3d}  ACC={out['K'][k]['acc']:.3f}", flush=True)
    tls = (2, 6) if fast else (1, 4, 10, 20)
    for tl in tls:
        cfg = dataclasses.replace(cfg0, local_rounds=tl)
        tr = make_method("SpreadFGL", cfg, batch)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=rounds)
        out["Tl"][tl] = {"acc": max(hist["acc"])}
        print(f"  Tl={tl:3d} ACC={out['Tl'][tl]['acc']:.3f}", flush=True)
    write_result("fig5_k_sensitivity", out)
    return out


if __name__ == "__main__":
    main()
