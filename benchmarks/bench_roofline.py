"""Roofline table: aggregates the dry-run JSON records into the §Roofline
report (terms in seconds, dominant bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import RESULTS, write_result

DRYRUN_DIR = RESULTS / "dryrun"


def load_records():
    recs = []
    if not DRYRUN_DIR.exists():
        return recs
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def main(fast: bool = False):
    print("[bench] roofline table (from dry-run records)")
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "failed"]
    if not recs:
        print("  (no dry-run records found — run "
              "`python -m repro.launch.dryrun --all --mesh both`)")
        return {}
    hdr = (f"  {'arch':22s} {'shape':12s} {'mesh':6s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} {'useful':>7s}")
    print(hdr)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_flops_ratio']:7.3f}")
    for r in skipped:
        print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{'SKIPPED (documented)':>40s}")
    for r in failed:
        print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
              f"{'FAILED':>40s}")
    summary = {"ok": len(ok), "skipped": len(skipped), "failed": len(failed)}
    print(f"  totals: {summary}")
    write_result("roofline_table", {"records": recs, "summary": summary})
    _write_markdown(ok, skipped, failed)
    return summary


def _write_markdown(ok, skipped, failed):
    """Render the §Roofline markdown table (pasted into EXPERIMENTS.md)."""
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful_flops | mem/device GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        tag = r.get("extra", {}).get("tag", "")
        arch = r["arch"] + (f" [{tag}]" if tag else "")
        mem = r.get("memory_per_device")
        mem_s = f"{mem/2**30:.1f}" if mem else "-"
        lines.append(
            f"| {arch} | {r['shape']} | {r['mesh']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {mem_s} |")
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                     f"SKIPPED | — | — |")
    for r in failed:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                     f"FAILED | — | — |")
    (RESULTS / "roofline_table.md").write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
