"""Table II: node classification accuracy, 4 datasets × 5 methods × M clients.

Reduced: datasets are SBM stand-ins at scale 0.15-0.2, M ∈ {6, 12} (the
paper's {6,9,12,15}), 14 communication rounds, averaged over seeds. The claim
validated is the ORDERING: SpreadFGL/FedGL ≥ FedAvg-fusion/FedSage+ >
LocalFGL.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import METHODS, fgl_setup, run_method, write_result


def run(plan, rounds=14, seeds=(1, 2, 3)) -> dict:
    table = {}
    for ds, m in plan:
        per_method = {method: {"acc": [], "f1": []} for method in METHODS}
        for seed in seeds:
            _, batch, cfg = fgl_setup(ds, m, seed=seed, scale=0.2)
            for method in METHODS:
                hist = run_method(method, cfg, batch, rounds=rounds, seed=seed)
                per_method[method]["acc"].append(max(hist["acc"]))
                per_method[method]["f1"].append(max(hist["f1"]))
        for method in METHODS:
            key = f"{ds}/M={m}/{method}"
            accs = per_method[method]["acc"]
            table[key] = {"acc": float(np.mean(accs)),
                          "acc_std": float(np.std(accs)),
                          "f1": float(np.mean(per_method[method]["f1"]))}
            print(f"  {key:44s} ACC={table[key]['acc']:.3f}"
                  f"±{table[key]['acc_std']:.3f}", flush=True)
    write_result("table2_accuracy", table)
    return table


def main(fast: bool = False):
    print("[bench] Table II — accuracy")
    if fast:
        return run([("cora", 6)], rounds=8, seeds=(1,))
    plan = [("cora", 6), ("cora", 12), ("citeseer", 6), ("citeseer", 12),
            ("wikics", 6), ("coauthor_cs", 6)]
    return run(plan)


if __name__ == "__main__":
    main()
