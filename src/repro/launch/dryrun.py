import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST run before any other import (jax locks the device
count on first initialization); 512 placeholder host devices stand in for the
2-pod production fleet.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun

Each run writes one JSON record (memory/cost analysis + collective bytes +
roofline terms) consumed by EXPERIMENTS.md §Dry-run/§Roofline and by
benchmarks/bench_roofline.py.
"""
import argparse
import dataclasses
import json
import pathlib
import time
from typing import Optional

import jax

from repro import configs
from repro.configs import INPUT_SHAPES, InputShape, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import decoding
from repro.models.config import ModelConfig
from repro.optim.adam import Adam
from repro.roofline import analysis, hw
from repro.sharding import specs as S
from repro.train.step import make_train_step


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh, *,
                    microbatch: int = 1):
    """Returns (fn, example_args) ready for jax.jit(fn).lower(*args)."""
    if shape.kind == "train":
        opt = Adam(lr=1e-4, clip_norm=1.0)
        step = make_train_step(cfg, opt, microbatch=microbatch)
        state = S.state_specs(cfg, mesh, opt)
        batch = S.batch_specs(cfg, shape, mesh)
        return step, (state, batch)
    if shape.kind == "prefill":
        params = S.param_specs(cfg, mesh)
        batch = S.batch_specs(cfg, shape, mesh)

        def prefill_fn(params, tokens, memory=None):
            return decoding.prefill(params, cfg, tokens, memory=memory)

        args = (params, batch["tokens"])
        if "memory" in batch:
            return (lambda p, t, m: decoding.prefill(p, cfg, t, memory=m),
                    (params, batch["tokens"], batch["memory"]))
        return prefill_fn, args
    # decode
    params = S.param_specs(cfg, mesh)
    cache = S.cache_specs(cfg, shape, mesh)
    token = S.token_spec(shape, mesh)

    def decode_fn(params, cache, token):
        return decoding.decode_step(params, cfg, cache, token)

    return decode_fn, (params, cache, token)


def run_one(arch: str, shape_name: str, mesh_name: str, out_dir: Optional[str],
            *, verbose: bool = True, microbatch: int = 1,
            seq_parallel: bool = False, attention_impl: str = "",
            no_scan: bool = False, tag: str = "") -> dict:
    shape = INPUT_SHAPES[shape_name]
    overrides = {}
    if seq_parallel:
        overrides["seq_parallel_activations"] = True
    if attention_impl:
        overrides["attention_impl"] = attention_impl
    if no_scan:
        overrides["scan_layers"] = False
    cfg = get_config(arch, "full", **overrides)
    if not shape_applicable(cfg, shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "long_500k requires sub-quadratic attention "
                         "(see DESIGN.md §4)"}
        _write(rec, out_dir, tag)
        return rec

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = hw.CHIPS_MULTI_POD if multi else hw.CHIPS_SINGLE_POD
    fn, args = build_lowerable(cfg, shape, mesh, microbatch=microbatch)

    t0 = time.time()
    # set_mesh (not plain `with mesh:`) so the abstract mesh is visible during
    # tracing — activation sharding constraints resolve against it.
    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem_text = None
    try:
        mem_text = str(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem_text = f"unavailable: {e}"

    rec_obj = analysis.analyze(compiled, arch=arch, shape=shape,
                               mesh_name=mesh_name, chips=chips, cfg=cfg,
                               extra={"lower_s": round(t_lower, 1),
                                      "compile_s": round(t_compile, 1),
                                      "microbatch": microbatch,
                                      "seq_parallel": seq_parallel,
                                      "tag": tag,
                                      "memory_analysis": mem_text})
    rec = {"status": "ok", **rec_obj.to_json()}
    if verbose:
        label = f"{arch} × {shape_name} × {mesh_name}" + (f" [{tag}]" if tag else "")
        print(f"[dryrun] {label}: "
              f"compute={rec_obj.compute_s:.4f}s memory={rec_obj.memory_s:.4f}s "
              f"collective={rec_obj.collective_s:.4f}s dominant={rec_obj.dominant} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"[dryrun]   memory_analysis: {mem_text[:300]}")
    _write(rec, out_dir, tag)
    return rec


def _write(rec: dict, out_dir: Optional[str], tag: str = ""):
    if not out_dir:
        return
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"
    (p / name).write_text(json.dumps(rec, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) combination")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="grad-accumulation chunks (perf iteration)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel activation constraints")
    ap.add_argument("--attention-impl", default="",
                    choices=("", "reference", "chunked"),
                    help="override attention path (perf iteration)")
    ap.add_argument("--no-scan", action="store_true",
                    help="unroll the layer stack (per-layer FSDP gathers)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output record (perf variants)")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        combos = [(a, s) for a in configs.ARCH_IDS for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        for mesh_name in meshes:
            if args.skip_existing and args.out:
                f = pathlib.Path(args.out) / f"{arch}_{shape}_{mesh_name}.json"
                if f.exists() and json.loads(f.read_text()).get("status") in ("ok", "skipped"):
                    print(f"[dryrun] skip existing {f.name}")
                    continue
            try:
                run_one(arch, shape, mesh_name, args.out,
                        microbatch=args.microbatch,
                        seq_parallel=args.seq_parallel,
                        attention_impl=args.attention_impl,
                        no_scan=args.no_scan, tag=args.tag)
            except Exception as e:  # noqa: BLE001
                print(f"[dryrun] FAILED {arch} × {shape} × {mesh_name}: {e!r}")
                failures.append((arch, shape, mesh_name, repr(e)))
                _write({"arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "failed", "error": repr(e)}, args.out)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
