import os
import sys

for _i, _a in enumerate(sys.argv):  # must precede the first jax import
    if _a == "--devices" and _i + 1 < len(sys.argv):
        _n = sys.argv[_i + 1]
    elif _a.startswith("--devices="):
        _n = _a.split("=", 1)[1]
    else:
        continue
    if _n.isdigit() and int(_n) > 0:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_n}").strip()
    break

"""Edge-server device mesh launcher.

Places SpreadFGL's stacked ``[N]`` edge-server axis (core/fedgl.py) on a JAX
device mesh so the vmapped imputation round runs data-parallel across devices:
each device owns ``N / mesh.size`` servers' autoencoder + assessor state and
their slice of the similarity/top-k work.

  # 4 emulated host devices, 4 edge servers, one server per device:
  PYTHONPATH=src python -m repro.launch.edge_mesh --devices 4 --servers 4

  # Decentralized gossip training: neighbor exchange every 4 rounds only,
  # executed as collective_permute across the mesh (Sec. III-E):
  PYTHONPATH=src python -m repro.launch.edge_mesh --devices 4 --servers 4 \\
      --gossip-every 4

On a 1-device host the mesh degenerates to size 1 (plain vmap) — same
numbers, no sharding. The ``--devices`` flag must be handled before the first
jax import (jax locks the device count on first initialization), hence the
header above. ``--gossip-every 0`` (the default) keeps dense per-round
Eq. 16 neighbor aggregation; any K >= 1 switches to the
``spreadfgl_gossip`` composition (K=1 is numerically the dense rule with
the exchange routed through the mesh collectives).

``--sim-shard`` additionally rotates the imputation round's CANDIDATE axis
around the same mesh as a ring (``core/ring_topk.py``): each device streams
every other device's candidate slab through collective_permute and folds it
into its running top-k — bit-identical results, 1/size candidate residency
per device.
"""
import argparse
import time

import jax

from repro.core.partition import partition_graph
from repro.core.spreadfgl import make_spreadfgl, make_spreadfgl_gossip
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph
from repro.launch.mesh import make_edge_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="emulated host device count (0 = use real devices)")
    ap.add_argument("--dataset", choices=tuple(DATASETS), default="cora")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--gossip-every", type=int, default=0,
                    help="cross-server exchange interval K (0 = dense "
                         "per-round Eq. 16 aggregation)")
    ap.add_argument("--sim-shard", action="store_true",
                    help="ring-rotate the imputation candidate axis around "
                         "the mesh (core/ring_topk.py; bit-identical results)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = make_edge_mesh(args.servers)
    print(f"[edge-mesh] {len(jax.devices())} device(s); mesh size {mesh.size} "
          f"for N={args.servers} edge servers")
    sim_mesh = mesh if args.sim_shard else None
    if args.sim_shard:
        print(f"[edge-mesh] sim shard: candidate slabs ring-rotate over "
              f"{mesh.size} device(s)")

    graph = make_sbm_graph(DATASETS[args.dataset], scale=0.15, seed=args.seed + 1,
                           feature_noise=3.0, signal_ratio=0.5)
    batch, _ = partition_graph(graph, args.clients, aug_max=12, seed=args.seed)
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=12,
                    gossip_every=max(args.gossip_every, 1))
    if args.gossip_every > 0:
        print(f"[edge-mesh] gossip aggregation: neighbor exchange every "
              f"{args.gossip_every} round(s) over the mesh")
        tr = make_spreadfgl_gossip(cfg, batch, num_servers=args.servers,
                                   gossip_every=args.gossip_every,
                                   edge_mesh=mesh, sim_mesh=sim_mesh)
    else:
        tr = make_spreadfgl(cfg, batch, num_servers=args.servers,
                            edge_mesh=mesh, sim_mesh=sim_mesh)

    state = tr.init(jax.random.key(args.seed), batch)
    placement = {d.id for leaf in jax.tree.leaves(state.ae_params)
                 for d in leaf.devices()}
    print(f"[edge-mesh] stacked generator state spans device(s) {sorted(placement)}")

    t0 = time.perf_counter()
    _, hist = tr.fit(jax.random.key(args.seed), batch, rounds=args.rounds)
    dt = time.perf_counter() - t0
    print(f"[edge-mesh] {args.rounds} rounds in {dt:.2f}s — "
          f"best acc={max(hist['acc']):.3f} f1={max(hist['f1']):.3f}")


if __name__ == "__main__":
    main()
