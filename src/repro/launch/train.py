"""LM training launcher (host-scale; the production mesh path is dryrun.py).

Runs real steps on whatever devices exist, with the same sharding rules as the
production mesh. ``--aggregation spread`` exercises the paper's gossip
aggregation across a ``pod`` axis (requires multiple host devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --variant smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.lm_data import token_batches
from repro.launch.mesh import make_host_mesh
from repro.optim.adam import Adam, cosine_schedule
from repro.train.step import init_state, make_train_step
from repro.checkpoint import io as ckpt_io


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="xlstm-125m")
    ap.add_argument("--variant", choices=("full", "smoke"), default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--aggregation", choices=("allreduce", "spread"),
                    default="allreduce")
    ap.add_argument("--gossip-every", type=int, default=4)
    ap.add_argument("--pods", type=int, default=0,
                    help="pod axis size for --aggregation spread")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, args.variant)
    opt = Adam(lr=args.lr, clip_norm=1.0,
               schedule=cosine_schedule(max(args.steps // 10, 1), args.steps))
    state = init_state(jax.random.key(0), cfg, opt)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} devices, aggregation={args.aggregation}")

    if args.aggregation == "spread":
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        pods = args.pods or len(jax.devices())
        mesh = make_host_mesh(pod=pods, data=1, model=1)
        step_inner = make_train_step(cfg, opt, aggregation="spread",
                                     gossip_every=args.gossip_every,
                                     pod_axis="pod")

        def per_pod(state_blk, batch_blk):
            # state stacked [pods, ...]; each pod sees its [1, ...] block.
            st = jax.tree.map(lambda t: t[0], state_blk)
            st, metrics = step_inner(st, batch_blk)
            return jax.tree.map(lambda t: t[None], st), metrics

        step = jax.jit(shard_map(per_pod, mesh=mesh,
                                 in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_rep=False))
        # replicate the initial state across pods (they diverge between gossips)
        state = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (pods,) + t.shape).copy(), state)
    else:
        step = jax.jit(make_train_step(cfg, opt))

    data = token_batches(cfg, batch=args.batch, seq_len=args.seq)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(jnp.mean(metrics["loss"]))
            print(f"[train] step {i:4d} loss {loss:.4f} "
                  f"({time.time()-t0:.1f}s)")
    if args.checkpoint:
        ckpt_io.save(args.checkpoint, state.params)
        print(f"[train] saved params -> {args.checkpoint}")


if __name__ == "__main__":
    main()
