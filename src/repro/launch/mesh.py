"""Production mesh definitions (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the "pod" axis
carries SpreadFGL's edge-server topology (core/gossip.py).

Functions, not module constants: importing this module never touches jax
device state (dryrun.py must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_edge_mesh(num_servers: int, *, devices: int = 0) -> Mesh:
    """1-D mesh carrying SpreadFGL's stacked [N] edge-server axis.

    Uses the largest divisor of ``num_servers`` that fits the available
    devices, so the vmapped imputation round always shards evenly (a 1-device
    host degenerates to a size-1 mesh, i.e. plain vmap).
    """
    n_dev = min(devices or len(jax.devices()), len(jax.devices()))
    size = max(d for d in range(1, min(num_servers, n_dev) + 1)
               if num_servers % d == 0)
    return Mesh(jax.devices()[:size], ("edge",))


def make_sim_mesh(*, devices: int = 0) -> Mesh:
    """1-D mesh carrying the CANDIDATE axis of the imputation similarity
    search (``core/ring_topk.py``; ``--sim-shard`` in the launchers).

    Unlike :func:`make_edge_mesh` there is no divisibility constraint — the
    ring driver pads the candidate axis to a mesh-size multiple — so this
    simply takes the first ``devices`` devices (default: all of them).
    """
    n = min(devices or len(jax.devices()), len(jax.devices()))
    return Mesh(jax.devices()[:n], ("sim",))


def make_host_mesh(*, model: int = 1, data: int = 0, pod: int = 0) -> Mesh:
    """Small mesh over whatever host devices exist (tests/examples)."""
    n = len(jax.devices())
    if pod:
        data = data or max(1, n // (model * pod))
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    data = data or max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
