"""FGL training launcher (the paper's experiments from the command line).

  PYTHONPATH=src python -m repro.launch.fgl_train \\
      --dataset cora --method SpreadFGL --clients 6 --servers 3 --rounds 12 \\
      [--local-rounds 4] [--imputation-interval 2] [--top-k 4] \\
      [--partitioner label_prop] [--alpha 1.0] [--participation 1.0] \\
      [--label-ratio 0.3] [--scale 0.15] [--feature-noise 3.0] \\
      [--signal-ratio 0.5] [--seed 0] [--impl reference] [--gossip-every 1] \\
      [--edge-mesh] [--sim-shard] [--json-out hist.json] \\
      [--save-state s.npz] [--resume s.npz]

Every method resolves through ``repro.core.registry`` — the same strategy
compositions the benchmarks and examples use (see ``registry.names()`` /
``docs/ARCHITECTURE.md``). ``--save-state`` checkpoints the final
``FGLState``; ``--resume`` restores one and continues Algorithm 1 at the
checkpointed round (true resume: imputation schedule AND gossip round-phase
intact). ``--impl`` selects the hot-path kernels for BOTH the per-client
classifier aggregation and the imputation round's fused similarity top-k:
``reference`` (jnp), ``pallas`` (TPU), or ``pallas_interpret`` (Pallas
kernels in interpret mode — bitwise the same code path as ``pallas``,
runnable on CPU). ``--gossip-every K`` (method ``spreadfgl_gossip``) makes
edge servers exchange parameters with topology neighbors only every K
rounds instead of dense per-round Eq. 16 averaging; combine with
``--edge-mesh`` to place the exchange on the device mesh. ``--sim-shard``
shards the CANDIDATE axis of the imputation similarity top-k across devices
(candidate slabs ring-rotate via collective_permute, ``core/ring_topk.py``);
the result is bit-identical to the single-device search, and when combined
with ``--edge-mesh`` one mesh carries both the [N] server axis and the
candidate ring.

Heterogeneity axis (``docs/BENCHMARKS.md``): ``--partitioner`` picks the
client-split strategy (``label_prop`` default, ``dirichlet`` label-skew
non-IID with concentration ``--alpha``, ``degree`` degree-skew, ``random``
edge-cut baseline); ``--participation R`` makes only ceil(R·M) clients
contribute to each round's aggregation (partial participation, R in (0,1]).

Straggler axis: ``--async-buffer B`` switches to FedBuff-style buffered
aggregation (method ``spreadfgl_async``; ``--method FedGL`` keeps the star
layout) — each round client updates report with arrival delays drawn from
``--delay-dist`` (``zero`` | ``uniform`` | ``geometric``) and are lost
mid-round with probability ``--dropout-rate``; the server flushes a
staleness-discounted (1/sqrt(1+tau)) weighted mean once B updates are
buffered instead of waiting for all M clients. The whole schedule is a pure
function of (seed, round), so ``--resume`` reproduces it exactly, and
``--async-buffer M --delay-dist zero`` is bit-identical to synchronous
FedAvg.
"""
from __future__ import annotations

import argparse
import json
import math

import jax

from repro.checkpoint import io as ckpt_io
from repro.core import registry
from repro.core.partition import (PARTITIONERS, count_missing_links,
                                  label_skew_entropy, make_partitioner,
                                  partition_graph)
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=tuple(DATASETS), default="cora")
    ap.add_argument("--method", default="SpreadFGL", choices=registry.names())
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--servers", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--local-rounds", type=int, default=4)
    ap.add_argument("--imputation-interval", "-K", type=int, default=2)
    ap.add_argument("--top-k", type=int, default=4)
    ap.add_argument("--partitioner", default="label_prop",
                    choices=tuple(sorted(PARTITIONERS)),
                    help="client-split strategy (heterogeneity axis): "
                         "label_prop (paper default), dirichlet (label-skew "
                         "non-IID, see --alpha), degree (degree-skew), "
                         "random (edge-cut baseline)")
    ap.add_argument("--alpha", type=float, default=1.0,
                    help="Dirichlet concentration for --partitioner "
                         "dirichlet (small = more label skew)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients participating in each round's "
                         "aggregation (rho in (0,1]; 1.0 = everyone, "
                         "bit-identical to runs without the flag)")
    ap.add_argument("--label-ratio", type=float, default=0.3)
    ap.add_argument("--scale", type=float, default=0.15)
    ap.add_argument("--feature-noise", type=float, default=3.0)
    ap.add_argument("--signal-ratio", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="reference",
                    choices=("reference", "pallas", "pallas_interpret"),
                    help="hot-path kernels for classifier aggregation and the "
                         "fused similarity top-k of the imputation round")
    ap.add_argument("--gossip-every", type=int, default=1,
                    help="cross-server exchange interval K for "
                         "spreadfgl_gossip (1 == dense-equivalent; selecting "
                         "a K forces the spreadfgl_gossip method)")
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="FedBuff-style buffered aggregation: flush when B "
                         "client updates are buffered instead of waiting for "
                         "all M (0 = synchronous; selecting B forces the "
                         "spreadfgl_async method)")
    ap.add_argument("--delay-dist", default="zero",
                    choices=("zero", "uniform", "geometric"),
                    help="client arrival-delay distribution for "
                         "--async-buffer (drawn from a key stream "
                         "f(seed, round), independent of the training key)")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-round probability a client update is lost "
                         "mid-round before reaching the buffer "
                         "(--async-buffer only; in [0, 1))")
    ap.add_argument("--json-out", default="")
    ap.add_argument("--save-state", default="",
                    help="write the final FGLState to this .npz")
    ap.add_argument("--resume", default="",
                    help="restore an FGLState .npz and continue at its round")
    ap.add_argument("--edge-mesh", action="store_true",
                    help="shard the [N] edge-server axis across devices "
                         "(SpreadFGL only)")
    ap.add_argument("--sim-shard", action="store_true",
                    help="shard the CANDIDATE axis of the imputation "
                         "similarity top-k across devices (ring rotation via "
                         "collective_permute, core/ring_topk.py); with "
                         "--edge-mesh the same mesh carries both axes")
    args = ap.parse_args()

    graph = make_sbm_graph(DATASETS[args.dataset], scale=args.scale,
                           seed=args.seed + 1, feature_noise=args.feature_noise,
                           signal_ratio=args.signal_ratio)
    part = make_partitioner(args.partitioner, alpha=args.alpha)
    batch, assign = partition_graph(graph, args.clients, aug_max=12,
                                    seed=args.seed, label_ratio=args.label_ratio,
                                    partitioner=part)
    ent = label_skew_entropy(assign, graph.y, args.clients)
    print(f"[fgl] {args.dataset}: {graph.num_nodes} nodes, "
          f"{count_missing_links(graph, assign)} missing cross-client links")
    print(f"[fgl] partitioner={args.partitioner} "
          f"mean client label entropy={ent.mean():.3f} nats")
    if not 0.0 < args.participation <= 1.0:
        ap.error("--participation must be in (0, 1]")
    if args.participation < 1.0:
        n_part = max(1, math.ceil(args.participation * args.clients))
        print(f"[fgl] partial participation: rho={args.participation} "
              f"({n_part} of {args.clients} clients aggregate per round)")

    if args.gossip_every < 1:
        ap.error("--gossip-every must be >= 1 (1 == exchange every round)")
    if args.gossip_every > 1:
        # Picking an exchange interval means gossip aggregation; only the
        # edge-server compositions have a cross-server exchange to schedule.
        if args.method == "SpreadFGL":
            args.method = "spreadfgl_gossip"
        elif args.method != "spreadfgl_gossip":
            ap.error(f"--gossip-every applies to SpreadFGL/spreadfgl_gossip, "
                     f"not --method {args.method}")
    if args.async_buffer < 0:
        ap.error("--async-buffer must be >= 0 (0 == synchronous)")
    if args.async_buffer > args.clients:
        ap.error(f"--async-buffer {args.async_buffer} can never fill with "
                 f"only {args.clients} clients (one buffer slot per client)")
    if not 0.0 <= args.dropout_rate < 1.0:
        ap.error("--dropout-rate must be in [0, 1)")
    if args.async_buffer > 0:
        # Picking a buffer size means buffered async aggregation; it replaces
        # the synchronous aggregator of the stock compositions. Async FedGL
        # keeps the star layout (one server covering all clients).
        if args.method == "FedGL":
            args.method, args.servers = "spreadfgl_async", 1
        elif args.method == "SpreadFGL":
            args.method = "spreadfgl_async"
        elif args.method != "spreadfgl_async":
            ap.error(f"--async-buffer applies to FedGL/SpreadFGL/"
                     f"spreadfgl_async, not --method {args.method}")
    elif args.method == "spreadfgl_async":
        ap.error("--method spreadfgl_async needs --async-buffer >= 1")
    cfg = FGLConfig(hidden_dim=32, local_rounds=args.local_rounds,
                    imputation_interval=args.imputation_interval,
                    top_k_links=args.top_k, aug_max=12,
                    label_ratio=args.label_ratio, kernel_impl=args.impl,
                    gossip_every=args.gossip_every,
                    async_buffer=args.async_buffer,
                    delay_dist=args.delay_dist,
                    dropout_rate=args.dropout_rate,
                    participation=args.participation, seed=args.seed)
    if args.impl != "reference":
        print(f"[fgl] kernel impl: {args.impl} (fused sim_topk + "
              f"sage_aggregate Pallas kernels)")
    kw = {}
    if args.method in ("SpreadFGL", "spreadfgl_gossip", "spreadfgl_async"):
        kw["num_servers"] = args.servers
        if args.edge_mesh:
            from repro.launch.mesh import make_edge_mesh
            kw["edge_mesh"] = make_edge_mesh(args.servers)
            print(f"[fgl] edge mesh: {kw['edge_mesh'].size} device(s) for "
                  f"N={args.servers}")
    if args.sim_shard:
        if args.method not in ("FedGL", "SpreadFGL", "spreadfgl_gossip",
                               "spreadfgl_async"):
            ap.error(f"--sim-shard needs an imputation round to shard; "
                     f"--method {args.method} has none")
        if "edge_mesh" in kw:
            # One mesh, two roles: the [N] server axis lives on it as data
            # placement, the candidate axis rotates around it as a ring —
            # mixing two Meshes in one jitted program is the fragile case.
            kw["sim_mesh"] = kw["edge_mesh"]
        else:
            from repro.launch.mesh import make_sim_mesh
            kw["sim_mesh"] = make_sim_mesh()
        print(f"[fgl] sim shard: candidate axis over "
              f"{kw['sim_mesh'].size} device(s)")
    if args.method == "spreadfgl_gossip":
        print(f"[fgl] gossip aggregation: cross-server exchange every "
              f"{args.gossip_every} round(s)")
    if args.method == "spreadfgl_async":
        print(f"[fgl] async aggregation: buffer B={args.async_buffer} of "
              f"M={args.clients}, delays={args.delay_dist}, "
              f"dropout={args.dropout_rate}")
    tr = registry.build(args.method, cfg, batch, **kw)

    if args.resume:
        state = ckpt_io.restore(args.resume,
                                tr.init(jax.random.key(args.seed), batch))
        print(f"[fgl] resumed {args.resume} at round {state.round}")
        state, hist = tr.fit(state=state, rounds=args.rounds)
    else:
        state, hist = tr.fit(jax.random.key(args.seed), batch,
                             rounds=args.rounds)
    for i, r in enumerate(hist["round"]):
        print(f"[fgl] round {r:3d} loss={hist['loss'][i]:8.4f} "
              f"acc={hist['acc'][i]:.3f} f1={hist['f1'][i]:.3f}")
    print(f"[fgl] best acc={max(hist['acc']):.3f} f1={max(hist['f1']):.3f}")
    if args.save_state:
        ckpt_io.save(args.save_state, state)
        print(f"[fgl] saved FGLState (round {state.round}) to {args.save_state}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(hist, f)


if __name__ == "__main__":
    main()
