import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Cross-pod aggregation dry-run: SpreadFGL gossip (Eq. 16) vs all-reduce.

Lowers BOTH aggregation schedules for a given architecture on the multi-pod
mesh and reports their collective traffic:

  allreduce : classic data parallelism — every step, psum of params/grads
              over the 'pod' axis (the FedAvg analogue, DESIGN.md §3).
  spread    : ring gossip — collective_permute with both ring neighbors,
              applied every K steps (the paper's edge-layer aggregation).

The per-step cross-pod byte ratio (gossip/K vs all-reduce) is the §Perf
measurement for the paper-representative hillclimb pair. All byte/ratio
math lives in ``repro.core.gossip`` — this CLI only lowers the two
schedules and reports. The *FGL engine* equivalent (gossip as a first-class
Aggregator strategy over the stacked [N] edge-server axis) is the
``spreadfgl_gossip`` registry method; ``benchmarks/bench_load_balance.py``
measures that path.

  PYTHONPATH=src python -m repro.launch.gossip_dryrun --arch qwen3-4b -K 8
"""
import argparse
import json
import pathlib

import jax
from jax.experimental.shard_map import shard_map

from repro import configs
from repro.core import gossip
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.roofline import analysis
from repro.sharding import rules, specs as S


def lower_aggregation(cfg, mesh, mode: str):
    params_specs = S.param_specs(cfg, mesh)
    shapes = jax.eval_shape(lambda: jax.tree.map(lambda s: s, params_specs))
    axes = transformer.model_axes(cfg)
    pspecs = rules.spec_tree(axes, params_specs, mesh)

    def agg(params):
        if mode == "spread":
            return gossip.ring_gossip(params, "pod")
        return gossip.all_average(params, "pod")

    fn = shard_map(agg, mesh=mesh, in_specs=(pspecs,), out_specs=pspecs,
                   check_rep=False)
    with jax.sharding.set_mesh(mesh):
        return jax.jit(fn).lower(params_specs).compile()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen3-4b")
    ap.add_argument("-K", "--gossip-every", type=int, default=8)
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, "full")
    mesh = make_production_mesh(multi_pod=True)
    out = {}
    for mode in ("allreduce", "spread"):
        compiled = lower_aggregation(cfg, mesh, mode)
        coll = analysis.collective_bytes(compiled.as_text())
        out[mode] = coll
        print(f"[gossip-dryrun] {args.arch} {mode}: {coll}")

    ar = sum(out["allreduce"].values())
    sp = sum(out["spread"].values())
    k = args.gossip_every
    # The byte-ratio math lives in core/gossip.py (shared with
    # benchmarks/bench_load_balance.py); this CLI is a thin caller.
    ratio = gossip.gossip_allreduce_ratio(ar, sp, every=k)
    print(f"[gossip-dryrun] per-step cross-pod bytes: allreduce={ar/1e9:.3f}GB "
          f"spread(K={k})={sp/k/1e9:.3f}GB ratio={ratio:.3f}")
    rec = {"arch": args.arch, "K": k, "allreduce_bytes": ar,
           "spread_bytes_per_application": sp,
           "spread_bytes_per_step": sp / k, "ratio": ratio,
           "detail": out}
    p = pathlib.Path(args.out)
    p.mkdir(parents=True, exist_ok=True)
    (p / f"gossip_{args.arch}_K{k}.json").write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
