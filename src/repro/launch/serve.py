"""Serving launcher: batched generation for any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import io as ckpt_io
from repro.data.lm_data import memory_stub
from repro.models import transformer
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--variant", choices=("full", "smoke"), default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, args.variant)
    params = transformer.init_model(jax.random.key(0), cfg)
    if args.checkpoint:
        params = ckpt_io.restore(args.checkpoint, params)
    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.steps + 8)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    memory = memory_stub(cfg, args.batch)

    t0 = time.time()
    out = engine.generate(prompts, steps=args.steps,
                          temperature=args.temperature, memory=memory)
    dt = time.time() - t0
    tput = args.batch * args.steps / dt
    print(f"[serve] {cfg.name}: {args.batch}×{args.steps} tokens in {dt:.2f}s "
          f"({tput:.1f} tok/s incl. compile)")
    for i, row in enumerate(out[:4]):
        print(f"  request {i}: {row[:16].tolist()}...")


if __name__ == "__main__":
    main()
