"""Core data structures for federated graph learning (SpreadFGL).

Shapes are static everywhere (padded + masked) so every training loop jits.

Conventions
-----------
- A *global* graph is ``Graph``: dense feature matrix, edge list, labels.
- A *federated* split is ``ClientBatch``: per-client padded subgraphs stacked on
  a leading client axis ``[M, ...]`` so client-local training vmaps.
- Imputation augments each client with ``aug_max`` extra node slots
  (the "graphic patcher" slots of Sec. III-D); they are zero/masked until the
  graph-fixing step fills them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

Array = Any  # jax or numpy array
PyTree = Any


@dataclasses.dataclass
class Graph:
    """A (global) undirected graph with node features and labels."""

    x: Array          # [n, d] float features
    senders: Array    # [e] int32
    receivers: Array  # [e] int32
    y: Array          # [n] int32 labels in [0, c)
    num_classes: int

    @property
    def num_nodes(self) -> int:
        return int(self.x.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.senders.shape[0])

    @property
    def feature_dim(self) -> int:
        return int(self.x.shape[1])

    def dense_adjacency(self) -> np.ndarray:
        """Dense symmetric 0/1 adjacency (numpy; for small graphs/tests)."""
        n = self.num_nodes
        a = np.zeros((n, n), dtype=np.float32)
        s = np.asarray(self.senders)
        r = np.asarray(self.receivers)
        a[s, r] = 1.0
        a[r, s] = 1.0
        np.fill_diagonal(a, 0.0)
        return a


import jax


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClientBatch:
    """Per-client padded subgraphs, stacked on a leading [M] axis.

    ``n_pad = n_local_max + aug_max``: the first ``n_local_max`` slots hold real
    local nodes, the trailing ``aug_max`` slots are reserved for imputed
    neighbors written by the graphic patcher (Sec. III-D).
    """

    x: Array           # [M, n_pad, d] features (aug slots overwritten by patcher)
    adj: Array         # [M, n_pad, n_pad] dense 0/1 adjacency (symmetric)
    y: Array           # [M, n_pad] labels (-1 on padding/aug slots)
    node_mask: Array   # [M, n_pad] 1.0 for real local nodes
    train_mask: Array  # [M, n_pad] 1.0 for labeled training nodes
    test_mask: Array   # [M, n_pad] 1.0 for held-out eval nodes
    global_id: Array   # [M, n_pad] int32 index into the global graph (-1 pad)
    num_classes: int = dataclasses.field(metadata=dict(static=True))
    aug_max: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_clients(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_pad(self) -> int:
        return int(self.x.shape[1])

    @property
    def n_local_max(self) -> int:
        return self.n_pad - self.aug_max

    def replace(self, **kw) -> "ClientBatch":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class FGLConfig:
    """Hyperparameters of FedGL / SpreadFGL (Sec. III, Table/Parameter settings)."""

    # GNN node classifier (GraphSAGE, GCN aggregator, 2 layers in the paper).
    hidden_dim: int = 64
    num_layers: int = 2
    gnn_kind: str = "sage"            # "sage" | "gcn" | "gat"
    dropout: float = 0.0

    # Hot-path kernel implementation, threaded through both compute hot spots
    # (gnn.aggregate in the client classifier and the fused similarity top-k
    # of the imputation round): "reference" (jnp), "pallas" (TPU kernels), or
    # "pallas_interpret" (the Pallas kernels in interpret mode — CPU parity).
    kernel_impl: str = "reference"

    # Federated schedule (Algorithm 1).
    num_edge_servers: int = 1          # N  (1 => FedGL, >1 => SpreadFGL)
    clients_per_server: int = 6        # M_j
    local_rounds: int = 10             # T_l
    global_rounds: int = 30            # T_g
    imputation_interval: int = 5       # K
    # Cross-server exchange interval for the gossip aggregator (Sec. III-E
    # distributed training): servers trade parameters with topology
    # neighbors every `gossip_every` rounds instead of dense per-round
    # Eq. 16 averaging. 1 == exchange every round (== NeighborAggregator on
    # the same adjacency). Only consumed by `spreadfgl_gossip` compositions.
    gossip_every: int = 1
    # Per-round partial client participation ρ ∈ (0, 1]: each global round
    # exactly ceil(ρ·M) clients (sampled without replacement from a key
    # stream independent of the training key) contribute to aggregation —
    # every Aggregator becomes a participation-mask-weighted mean. ρ = 1
    # disables the feature entirely (no mask is sampled, no key is consumed;
    # fixed-seed histories are bit-identical to pre-participation runs).
    # The round-t mask is a pure function of (seed, t), so save/resume
    # reproduces the schedule exactly. CLI: `fgl_train --participation`.
    participation: float = 1.0
    # FedBuff-style async aggregation (Sec. III-E straggler tolerance).
    # async_buffer = B > 0 turns aggregation into a buffered flush: client
    # updates report with per-round arrival delays drawn from `delay_dist`
    # ("zero" | "uniform" | "geometric", capped at async_max_delay) and are
    # lost mid-round with probability dropout_rate; the server aggregates
    # (staleness-discounted, 1/sqrt(1+τ)) only when ≥ B updates are
    # buffered. 0 disables the feature (synchronous aggregation, no async
    # key stream is consumed). B = M with zero delays reproduces FedAvg
    # bit-identically. CLI: `fgl_train --async-buffer/--delay-dist`.
    async_buffer: int = 0
    delay_dist: str = "zero"
    dropout_rate: float = 0.0
    async_max_delay: int = 4
    ae_iters: int = 5                  # T_ae
    assessor_iters: int = 3           # T_as
    ae_outer_iters: int = 3            # "while not convergent" outer loop bound

    # Imputation generator / assessor (Sec. III-C/D).
    top_k_links: int = 5               # k most-similar cross-subgraph links
    ae_hidden: int = 16                # autoencoder bottleneck {c,16,d}/{d,16,c}
    assessor_hidden: tuple = (128, 16) # assessor MLP {c,128,16,1}
    neg_threshold: Optional[float] = None  # theta; default 1/c
    aug_max: int = 16                  # patcher slots per client

    # Optimization.
    lr_classifier: float = 0.01        # Adam, paper Sec. IV-A
    lr_generator: float = 0.001        # Adam for AE + assessor
    trace_reg: float = 1e-4            # Eq. 15 trace-norm coefficient (SpreadFGL)
    label_ratio: float = 0.3

    seed: int = 0

    def theta(self, num_classes: int) -> float:
        return self.neg_threshold if self.neg_threshold is not None else 1.0 / num_classes
