"""Graph fixing via local graphic patchers (Sec. III-D).

The edge server splits the learnable potential graph G̅ = (V, E̅, X̅) back into
per-client pieces; each client's patcher P_i^j merges its piece into the local
subgraph: imputed cross-subgraph neighbors become *augmented node slots*
(features from X̅ = f(S)) wired to the local nodes they were matched with.
This restores multi-hop feature propagation without ever moving raw features
between clients — only AE-generated ones.

Static shapes: every client owns ``aug_max`` augmentation slots; each fixing
round overwrites them (links from previous rounds are superseded, which matches
the paper's per-round regeneration of G̅).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ClientBatch


def stitch_server_links(scores: jnp.ndarray, idx: jnp.ndarray, x_bar: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-server imputation results -> the global flat index space.

    Each edge server emits link targets as *server-local* flat slots in
    ``[0, M_per * n_pad)``; server j's slots live at global offset
    ``j * M_per * n_pad`` (clients are grouped contiguously per server).

    Args:
      scores: [N, M_per*n_pad, k] link similarities.
      idx: [N, M_per*n_pad, k] server-local flat targets, -1 where invalid.
      x_bar: [N, M_per*n_pad, d] imputed features X̅.

    Returns (scores [M*n_pad, k], idx [M*n_pad, k] global flats, x_bar
    [M*n_pad, d]).
    """
    n, n_flat, k = idx.shape
    offsets = (jnp.arange(n, dtype=idx.dtype) * n_flat)[:, None, None]
    idx = jnp.where(idx >= 0, idx + offsets, -1)
    return (scores.reshape(n * n_flat, k), idx.reshape(n * n_flat, k),
            x_bar.reshape(n * n_flat, x_bar.shape[-1]))


def fix_graphs(batch: ClientBatch, link_scores: jnp.ndarray, link_idx: jnp.ndarray,
               x_bar: jnp.ndarray) -> ClientBatch:
    """Apply graph fixing to every client.

    Args:
      batch: current federated batch (aug slots will be overwritten).
      link_scores: [M*n_pad, k] similarity of imputed links (0 = invalid).
      link_idx: [M*n_pad, k] flat global slot of the matched cross-subgraph
        node, -1 where invalid.
      x_bar: [M*n_pad, d] imputed potential features X̅ (AE encoder output).

    Returns a new ClientBatch with aug slots populated.
    """
    m, n_pad = batch.x.shape[0], batch.x.shape[1]
    aug_max = batch.aug_max
    n_local = n_pad - aug_max
    d = batch.x.shape[2]

    scores = link_scores.reshape(m, n_pad, -1)
    idx = link_idx.reshape(m, n_pad, -1)
    k = scores.shape[-1]

    def fix_one(x, adj, node_mask, sc, ix):
        # Candidate links from this client's *real local* nodes.
        src = jnp.broadcast_to(jnp.arange(n_pad)[:, None], (n_pad, k)).reshape(-1)
        tgt = ix.reshape(-1)
        s = sc.reshape(-1)
        is_local_src = (src < n_local) & (node_mask[src] > 0)
        valid = (tgt >= 0) & is_local_src
        s = jnp.where(valid, s, -jnp.inf)
        # Strongest aug_max links win the augmentation slots.
        top_s, top_i = jax.lax.top_k(s, aug_max)
        chosen_src = src[top_i]
        chosen_tgt = tgt[top_i]
        chosen_ok = jnp.isfinite(top_s)

        aug_rows = n_local + jnp.arange(aug_max)
        safe_tgt = jnp.maximum(chosen_tgt, 0)
        feats = x_bar[safe_tgt] * chosen_ok[:, None]

        # Reset aug region, then write features + symmetric links.
        x = x.at[n_local:].set(0.0)
        x = x.at[aug_rows].set(feats.astype(x.dtype))
        adj = adj.at[n_local:, :].set(0.0)
        adj = adj.at[:, n_local:].set(0.0)
        w = chosen_ok.astype(adj.dtype)
        adj = adj.at[chosen_src, aug_rows].set(w)
        adj = adj.at[aug_rows, chosen_src].set(w)
        node_mask = node_mask.at[aug_rows].set(w)
        return x, adj, node_mask

    x, adj, node_mask = jax.vmap(fix_one)(batch.x, batch.adj, batch.node_mask,
                                          scores, idx)
    return batch.replace(x=x, adj=adj, node_mask=node_mask)


def clear_augmentation(batch: ClientBatch) -> ClientBatch:
    """Drop all imputed nodes/links (used by baselines and ablations)."""
    n_local = batch.n_local_max
    x = batch.x.at[:, n_local:].set(0.0) if hasattr(batch.x, "at") else batch.x
    adj = batch.adj
    if hasattr(adj, "at"):
        adj = adj.at[:, n_local:, :].set(0.0)
        adj = adj.at[:, :, n_local:].set(0.0)
    mask = batch.node_mask
    if hasattr(mask, "at"):
        mask = mask.at[:, n_local:].set(0.0)
    return batch.replace(x=x, adj=adj, node_mask=mask)
