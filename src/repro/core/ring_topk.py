"""Candidate-sharded ring top-k for the imputation similarity topology.

The adaptive generator's A̅ = H Hᵀ + cross-subgraph top-k (Sec. III-C) is the
FGL-side compute wall: every single-device path in ``imputation.
similarity_topk`` streams gram slabs against ALL n candidates — O(q·c·n) per
edge server, with the whole candidate set resident on one device. This module
distributes the CANDIDATE axis across the edge mesh instead, reusing the ring
``collective_permute`` schedule idiom of ``core/gossip.block_ring_gossip``:

- Each of the ``size`` mesh devices owns an ``[n/size, c]`` slice of the
  candidate features plus the matching client-id / target-mask slices (and an
  ``[q/size, c]`` slice of the query rows — in production queries ARE the
  candidates, every node needs links).
- Candidate slabs rotate around the ring: ``size`` fold steps, ``size - 1``
  single-neighbor ``collective_permute`` sends, each moving one slab of
  ``ring_rotation_bytes`` — never an all-gather of the candidate set.
- Each device folds the visiting slab into its running (vals, idx) top-k with
  :func:`repro.kernels.sim_topk.topk_merge` — the SAME streaming merge the
  fused Pallas kernel uses — offsetting slab-local columns by
  ``owner · n/size`` to global candidate indices. The merge tie-breaks by
  smallest global index (not arrival order), so the fold is invariant to the
  rotation order the shards arrive in.
- After ``size`` steps NO final gather/reduce of scores is needed: every
  device has already seen every candidate shard, so its partial top-k IS the
  exact global top-k for its query rows. The only output collective is the
  layout-level reassembly of the ``[q, k]`` result.

The result is bit-identical to the single-device reference (pinned in
``tests/test_ring_topk.py`` on 2/4/8 emulated devices, including
non-divisible n, fully-masked rows, ties, and k > valid candidates).

Byte/FLOP accounting for the scaling benchmark
(``benchmarks/bench_sim_scaling.py``) lives at the bottom, next to the
gossip byte model's conventions in ``core/gossip.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sim_topk import topk_merge


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int, value) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def fold_slab(run_v: jnp.ndarray, run_i: jnp.ndarray,
              rows: jnp.ndarray, row_cid: jnp.ndarray,
              cand: jnp.ndarray, cand_cid: jnp.ndarray,
              cand_mask: jnp.ndarray, offset) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fold one candidate slab into the running top-k of the query rows.

    rows: [..., q, c]; cand: [..., m, c]; the gram tile is masked to
    cross-subgraph valid targets and merged via :func:`topk_merge` with
    slab-local columns shifted by ``offset`` to global candidate indices.
    """
    s = jnp.einsum("...qc,...nc->...qn", rows, cand)
    keep = ((row_cid[..., :, None] != cand_cid[..., None, :])
            & (cand_mask[..., None, :] > 0))
    s = jnp.where(keep, s, -jnp.inf)
    col = offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, s.ndim - 1)
    return topk_merge(run_v, run_i, s, col)


def _ring_fold(rows, row_cid, cand, cand_cid, cand_mask, *, k: int,
               axis: Optional[str], size: int):
    """The per-shard ring schedule: ``size`` folds, ``size - 1`` rotations.

    Runs inside ``shard_map`` when ``axis`` names a mesh axis (each argument
    is this device's slice) or standalone with ``axis=None, size=1`` (single
    slab covering the whole candidate axis — the degenerate mesh).
    """
    shard_n = cand.shape[-2]
    run_v = jnp.full(rows.shape[:-1] + (k,), -jnp.inf, jnp.float32)
    run_i = jnp.full(rows.shape[:-1] + (k,), -1, jnp.int32)
    if axis is None or size == 1:
        return fold_slab(run_v, run_i, rows, row_cid,
                         cand, cand_cid, cand_mask, 0)
    me = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % size) for i in range(size)]
    for step in range(size):
        # After ``step`` forward rotations this device holds the slab that
        # started on device (me - step) % size — its global index offset.
        owner = jnp.mod(me - step, size)
        run_v, run_i = fold_slab(run_v, run_i, rows, row_cid,
                                 cand, cand_cid, cand_mask, owner * shard_n)
        if step != size - 1:
            cand = jax.lax.ppermute(cand, axis, perm)
            cand_cid = jax.lax.ppermute(cand_cid, axis, perm)
            cand_mask = jax.lax.ppermute(cand_mask, axis, perm)
    return run_v, run_i


def ring_similarity_topk(h: jnp.ndarray, client_ids: jnp.ndarray,
                         target_mask: jnp.ndarray, k: int, *, mesh,
                         queries: Optional[jnp.ndarray] = None,
                         query_cid: Optional[jnp.ndarray] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact global masked top-k with the candidate axis sharded on ``mesh``.

    h: ``[n, c]`` or batched ``[B, n, c]`` candidate features (the stacked
    [N]-server axis of the engine rides along replicated — each batch element
    keeps its own candidate set, never mixed across servers); client_ids
    ``[.., n]`` int; target_mask ``[.., n]`` valid-target mask. ``queries``
    (default: h — every node queries, the production case) may be any
    ``[.., q, c]`` row subset with its ``query_cid``; both axes are padded to
    mesh-size multiples internally (candidate padding carries mask 0, so it
    can never be selected; padded query rows are sliced off).

    Returns RAW (vals [.., q, k] f32 with -inf on missing candidates,
    idx [.., q, k] int32 with -1 where never filled) — the caller
    (``imputation.similarity_topk``) applies the (0.0, -1) convention.
    """
    if queries is None:
        queries, query_cid = h, client_ids
    batched = h.ndim == 3
    if not batched:
        h, client_ids, target_mask = (h[None], client_ids[None],
                                      target_mask[None])
        queries, query_cid = queries[None], query_cid[None]
    q = queries.shape[1]
    size = int(mesh.size)

    cid = client_ids.astype(jnp.int32)
    tmask = target_mask.astype(jnp.float32)
    qcid = query_cid.astype(jnp.int32)
    if size > 1:
        # Pad both axes to mesh-size multiples; padded candidates carry
        # mask 0 (never selected), padded query rows are sliced off below.
        h = _pad_axis(h, 1, size, 0.0)
        cid = _pad_axis(cid, 1, size, -1)
        tmask = _pad_axis(tmask, 1, size, 0.0)
        queries = _pad_axis(queries, 1, size, 0.0)
        qcid = _pad_axis(qcid, 1, size, -1)

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        axis = mesh.axis_names[0]
        sheet = P(None, axis)

        def shard_fn(qry, qc, cand, cc, cm):
            return _ring_fold(qry, qc, cand, cc, cm, k=k, axis=axis,
                              size=size)

        vals, idx = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(None, axis, None), sheet,
                      P(None, axis, None), sheet, sheet),
            out_specs=(P(None, axis, None), P(None, axis, None)),
            check_rep=False)(queries, qcid, h, cid, tmask)
    else:
        vals, idx = _ring_fold(queries, qcid, h, cid, tmask, k=k,
                               axis=None, size=1)
    vals, idx = vals[:, :q], idx[:, :q]
    if not batched:
        vals, idx = vals[0], idx[0]
    return vals, idx


# ---------------------------------------------------------------------------
# Traffic / FLOP accounting (bench_sim_scaling; conventions as core/gossip.py).
# ---------------------------------------------------------------------------

def sim_topk_flops(q: int, n: int, c: int) -> float:
    """MXU FLOPs of the masked top-k sweep: the q×n gram at 2·c each.

    The streaming merge's compares are excluded (vector-unit noise next to
    the gram), matching the fused-kernel accounting in bench_kernels.
    """
    return 2.0 * q * n * c


def ring_rotation_bytes(n: int, c: int, size: int, *,
                        itemsize: int = 4) -> float:
    """Bytes ONE device sends per rotation step: its current candidate slab.

    Each step permutes the [n/size, c] feature slab plus the [n/size]
    client-id (int32) and target-mask (float32) slices to one ring neighbor.
    """
    if size <= 1:
        return 0.0
    shard = (n + size - 1) // size
    return float(shard * (c * itemsize + 4 + 4))


def ring_total_bytes(n: int, c: int, size: int, *, itemsize: int = 4) -> float:
    """Per-device cross-device bytes of one full sweep: size-1 rotations.

    Compare ``allgather_bytes``: rotating slabs moves the same total volume
    as a ring all-gather of the candidates WOULD, but peak per-device
    residency stays at one slab instead of the full [n, c] matrix — that is
    what makes million-node candidate sets fit.
    """
    return (size - 1) * ring_rotation_bytes(n, c, size, itemsize=itemsize)


def allgather_bytes(n: int, c: int, size: int, *, itemsize: int = 4) -> float:
    """Per-device bytes of the rejected alternative: all-gather candidates
    then run the single-device kernel on the full [n, c] locally."""
    if size <= 1:
        return 0.0
    return (size - 1) / size * float(n * (c * itemsize + 4 + 4))
