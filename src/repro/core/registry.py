"""Named FGL method registry.

Every trainer the launchers, benchmarks, and examples expose is a *strategy
composition* — a :class:`~repro.core.fedgl.FGLTrainer` assembled from a
Topology, an Aggregator, and an ImputationStrategy (see
:mod:`repro.core.strategies`) — registered here under the name the CLI uses:

    from repro.core import registry
    trainer = registry.build("SpreadFGL", cfg, batch, num_servers=3)

Stock methods (see ``docs/PAPER_MAP.md`` for the paper mapping):
``FedGL``, ``SpreadFGL``, ``spreadfgl_gossip`` (decentralized gossip
aggregation over the edge mesh, Sec. III-E), ``spreadfgl_async`` (FedBuff-
style buffered straggler-tolerant aggregation, Sec. III-E), ``local``,
``fedavg_fusion``, ``fedsage_plus``.

Builders register themselves at import time via :func:`register`; resolving a
name lazily imports the modules that define the stock methods
(``repro.core.spreadfgl`` and ``repro.core.baselines``), so importing this
module alone never pulls in the engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

Builder = Callable[..., Any]  # (cfg, batch, **kw) -> FGLTrainer

_BUILDERS: Dict[str, Builder] = {}


def register(name: str) -> Callable[[Builder], Builder]:
    """Decorator: expose ``builder(cfg, batch, **kw)`` under ``name``."""
    def deco(builder: Builder) -> Builder:
        if name in _BUILDERS and _BUILDERS[name] is not builder:
            raise ValueError(f"method {name!r} already registered")
        _BUILDERS[name] = builder
        return builder
    return deco


def _populate() -> None:
    # Stock methods self-register on import.
    import repro.core.baselines   # noqa: F401
    import repro.core.spreadfgl   # noqa: F401


def build(name: str, cfg, batch, **kw):
    """Construct the registered method ``name`` for (cfg, batch)."""
    _populate()
    try:
        builder = _BUILDERS[name]
    except KeyError:
        raise KeyError(f"unknown FGL method {name!r}; "
                       f"available: {', '.join(names())}") from None
    return builder(cfg, batch, **kw)


def names() -> tuple:
    """All registered method names (sorted)."""
    _populate()
    return tuple(sorted(_BUILDERS))
