"""Graph partitioning into disjoint client subgraphs (Sec. III-A).

The paper uses Louvain to split each benchmark graph into M client subgraphs
with *no shared nodes and no cross-client links* (the deleted links are the
missing cross-subgraph links the imputation generator must recover). Offline we
use deterministic label propagation as the community detector, then balance the
communities into M equal-size clients.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.types import ClientBatch, Graph


def label_propagation_communities(graph: Graph, *, iters: int = 20, seed: int = 0) -> np.ndarray:
    """Deterministic synchronous label propagation; returns [n] community ids."""
    n = graph.num_nodes
    rng = np.random.default_rng(seed)
    labels = np.arange(n)
    nbrs: List[List[int]] = [[] for _ in range(n)]
    for u, v in zip(np.asarray(graph.senders), np.asarray(graph.receivers)):
        nbrs[int(u)].append(int(v))
        nbrs[int(v)].append(int(u))
    order = rng.permutation(n)
    for _ in range(iters):
        changed = 0
        for u in order:
            if not nbrs[u]:
                continue
            counts = np.bincount(labels[nbrs[u]])
            best = int(np.argmax(counts))
            if labels[u] != best:
                labels[u] = best
                changed += 1
        if changed == 0:
            break
    # Compact ids.
    _, labels = np.unique(labels, return_inverse=True)
    return labels.astype(np.int32)


def balanced_assignment(communities: np.ndarray, num_clients: int, *, seed: int = 0) -> np.ndarray:
    """Pack communities into ``num_clients`` near-equal groups (greedy bin pack)."""
    rng = np.random.default_rng(seed)
    comm_ids, counts = np.unique(communities, return_counts=True)
    order = np.argsort(-counts)  # largest community first
    loads = np.zeros(num_clients, dtype=np.int64)
    comm_to_client = {}
    for idx in order:
        cid = int(comm_ids[idx])
        target = int(np.argmin(loads))
        comm_to_client[cid] = target
        loads[target] += counts[idx]
    assign = np.array([comm_to_client[int(c)] for c in communities], dtype=np.int32)
    # Rebalance: move random nodes from overloaded to underloaded clients so that
    # every client has at least one node and sizes stay within 2x of mean.
    n = len(assign)
    mean = n / num_clients
    for c in range(num_clients):
        while np.sum(assign == c) > 2 * mean:
            donor = np.where(assign == c)[0]
            tgt = int(np.argmin(np.bincount(assign, minlength=num_clients)))
            assign[rng.choice(donor)] = tgt
    for c in range(num_clients):
        if not np.any(assign == c):
            big = int(np.argmax(np.bincount(assign, minlength=num_clients)))
            movable = np.where(assign == big)[0]
            assign[rng.choice(movable)] = c
    return assign


def count_missing_links(graph: Graph, assign: np.ndarray) -> int:
    """|ΔE|: links deleted because their endpoints land on different clients."""
    s = np.asarray(graph.senders)
    r = np.asarray(graph.receivers)
    return int(np.sum(assign[s] != assign[r]))


def partition_graph(graph: Graph, num_clients: int, *, label_ratio: float = 0.3,
                    test_ratio: float = 0.2, aug_max: int = 16,
                    seed: int = 0) -> Tuple[ClientBatch, np.ndarray]:
    """Split ``graph`` into M disjoint padded client subgraphs.

    Cross-client edges are DELETED (they are the missing links of Sec. III-A);
    their count is reported by :func:`count_missing_links`.

    Returns (client_batch, assign).
    """
    rng = np.random.default_rng(seed)
    comm = label_propagation_communities(graph, seed=seed)
    assign = balanced_assignment(comm, num_clients, seed=seed)

    sizes = np.bincount(assign, minlength=num_clients)
    n_local_max = int(sizes.max())
    n_pad = n_local_max + aug_max
    d = graph.feature_dim
    m = num_clients

    x = np.zeros((m, n_pad, d), dtype=np.float32)
    adj = np.zeros((m, n_pad, n_pad), dtype=np.float32)
    y = -np.ones((m, n_pad), dtype=np.int32)
    node_mask = np.zeros((m, n_pad), dtype=np.float32)
    train_mask = np.zeros((m, n_pad), dtype=np.float32)
    test_mask = np.zeros((m, n_pad), dtype=np.float32)
    global_id = -np.ones((m, n_pad), dtype=np.int32)

    s = np.asarray(graph.senders)
    r = np.asarray(graph.receivers)
    gx = np.asarray(graph.x)
    gy = np.asarray(graph.y)

    for ci in range(m):
        nodes = np.where(assign == ci)[0]
        k = len(nodes)
        local_index = {int(g): i for i, g in enumerate(nodes)}
        x[ci, :k] = gx[nodes]
        y[ci, :k] = gy[nodes]
        node_mask[ci, :k] = 1.0
        global_id[ci, :k] = nodes
        # Intra-client edges only.
        keep = (assign[s] == ci) & (assign[r] == ci)
        for u, v in zip(s[keep], r[keep]):
            iu, iv = local_index[int(u)], local_index[int(v)]
            adj[ci, iu, iv] = 1.0
            adj[ci, iv, iu] = 1.0
        # Label split: label_ratio train, test_ratio test (disjoint).
        perm = rng.permutation(k)
        n_tr = max(1, int(round(label_ratio * k)))
        n_te = max(1, int(round(test_ratio * k)))
        train_mask[ci, perm[:n_tr]] = 1.0
        test_mask[ci, perm[n_tr:n_tr + n_te]] = 1.0

    batch = ClientBatch(x=x, adj=adj, y=y, node_mask=node_mask,
                        train_mask=train_mask, test_mask=test_mask,
                        global_id=global_id, num_classes=graph.num_classes,
                        aug_max=aug_max)
    return batch, assign


def group_clients_by_server(num_clients: int, num_servers: int) -> np.ndarray:
    """[M] -> server id; contiguous balanced grouping (clients talk to nearest server)."""
    return (np.arange(num_clients) * num_servers // num_clients).astype(np.int32)


def ring_adjacency(num_servers: int, *, self_loop: bool = True) -> np.ndarray:
    """Edge-layer topology A of Sec. III-E (paper testbed uses a ring)."""
    a = np.zeros((num_servers, num_servers), dtype=np.float32)
    if num_servers == 1:
        return np.ones((1, 1), dtype=np.float32)
    for j in range(num_servers):
        a[j, (j - 1) % num_servers] = 1.0
        a[j, (j + 1) % num_servers] = 1.0
        if self_loop:
            a[j, j] = 1.0
    return a
