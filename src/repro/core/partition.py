"""Graph partitioning into disjoint client subgraphs (Sec. III-A).

The paper uses Louvain to split each benchmark graph into M client subgraphs
with *no shared nodes and no cross-client links* (the deleted links are the
missing cross-subgraph links the imputation generator must recover). Offline we
use deterministic label propagation as the community detector, then balance the
communities into M equal-size clients.

That homogeneous community split is only ONE point on the heterogeneity axis
the FGL literature stresses (AdaFGL's topology heterogeneity, FedGTA's non-IID
subgraphs). Partitioning is therefore pluggable: a :class:`Partitioner`
strategy produces the ``[n] -> client`` assignment and
:func:`partition_graph` is a thin dispatcher that turns any assignment into
the padded :class:`~repro.core.types.ClientBatch` the engine trains on.

Strategies (``PARTITIONERS`` registry, CLI ``fgl_train --partitioner``):

- ``label_prop`` — :class:`LabelPropagationPartitioner`, the default; bit-
  compatible with the pre-protocol ``partition_graph`` (the fixed-seed
  goldens in ``tests/test_strategy_api.py`` pin this).
- ``dirichlet`` — :class:`DirichletPartitioner`, α-parameterized label-skew
  non-IID (per class, client shares drawn from Dir(α·1_M); α→∞ is IID,
  α→0 gives each client a handful of classes).
- ``degree`` — :class:`DegreeSkewPartitioner`, topology heterogeneity:
  clients own contiguous slices of the degree ordering (client 0 the
  sparsest nodes, client M-1 the hubs).
- ``random`` — :class:`RandomEdgeCutPartitioner`, uniform random node
  assignment; the expected (1 - 1/M) edge-cut baseline.

Every strategy returns the same ``assign`` contract: an ``[n]`` int32 array
with every node assigned to exactly one client in ``[0, M)`` and every
client non-empty, deterministic per ``(graph, num_clients, seed)``
(``tests/test_partitioners.py`` property-checks all of them).
"""
from __future__ import annotations

import dataclasses
from typing import List, Protocol, Tuple, Union, runtime_checkable

import numpy as np

from repro.core.types import ClientBatch, Graph


def label_propagation_communities(graph: Graph, *, iters: int = 20, seed: int = 0) -> np.ndarray:
    """Deterministic synchronous label propagation; returns [n] community ids."""
    n = graph.num_nodes
    rng = np.random.default_rng(seed)
    labels = np.arange(n)
    nbrs: List[List[int]] = [[] for _ in range(n)]
    for u, v in zip(np.asarray(graph.senders), np.asarray(graph.receivers)):
        nbrs[int(u)].append(int(v))
        nbrs[int(v)].append(int(u))
    order = rng.permutation(n)
    for _ in range(iters):
        changed = 0
        for u in order:
            if not nbrs[u]:
                continue
            counts = np.bincount(labels[nbrs[u]])
            best = int(np.argmax(counts))
            if labels[u] != best:
                labels[u] = best
                changed += 1
        if changed == 0:
            break
    # Compact ids.
    _, labels = np.unique(labels, return_inverse=True)
    return labels.astype(np.int32)


def balanced_assignment(communities: np.ndarray, num_clients: int, *, seed: int = 0) -> np.ndarray:
    """Pack communities into ``num_clients`` near-equal groups (greedy bin pack)."""
    rng = np.random.default_rng(seed)
    comm_ids, counts = np.unique(communities, return_counts=True)
    order = np.argsort(-counts)  # largest community first
    loads = np.zeros(num_clients, dtype=np.int64)
    comm_to_client = {}
    for idx in order:
        cid = int(comm_ids[idx])
        target = int(np.argmin(loads))
        comm_to_client[cid] = target
        loads[target] += counts[idx]
    assign = np.array([comm_to_client[int(c)] for c in communities], dtype=np.int32)
    # Rebalance: move random nodes from overloaded to underloaded clients so that
    # every client has at least one node and sizes stay within 2x of mean.
    n = len(assign)
    mean = n / num_clients
    for c in range(num_clients):
        while np.sum(assign == c) > 2 * mean:
            donor = np.where(assign == c)[0]
            tgt = int(np.argmin(np.bincount(assign, minlength=num_clients)))
            assign[rng.choice(donor)] = tgt
    for c in range(num_clients):
        if not np.any(assign == c):
            big = int(np.argmax(np.bincount(assign, minlength=num_clients)))
            movable = np.where(assign == big)[0]
            assign[rng.choice(movable)] = c
    return assign


# ---------------------------------------------------------------------------
# Partitioner strategies.
# ---------------------------------------------------------------------------

@runtime_checkable
class Partitioner(Protocol):
    """Produce the [n] -> client assignment (the heterogeneity axis).

    ``assign`` must place every node on exactly one client in ``[0, M)``,
    leave no client empty, and be deterministic per ``seed``.
    """

    def assign(self, graph: Graph, num_clients: int, *, seed: int = 0) -> np.ndarray: ...


def _fill_empty_clients(assign: np.ndarray, num_clients: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Move one random node from the largest client onto each empty client."""
    for c in range(num_clients):
        if not np.any(assign == c):
            big = int(np.argmax(np.bincount(assign, minlength=num_clients)))
            movable = np.where(assign == big)[0]
            assign[rng.choice(movable)] = c
    return assign


@dataclasses.dataclass(frozen=True)
class LabelPropagationPartitioner:
    """Community split + greedy balancing (the paper's Sec. III-A setup).

    The default and the pre-protocol behavior of :func:`partition_graph`,
    kept bit-compatible: label propagation and balancing consume their own
    ``default_rng(seed)`` streams exactly as before.
    """

    iters: int = 20

    def assign(self, graph: Graph, num_clients: int, *, seed: int = 0) -> np.ndarray:
        comm = label_propagation_communities(graph, iters=self.iters, seed=seed)
        return balanced_assignment(comm, num_clients, seed=seed)


@dataclasses.dataclass(frozen=True)
class DirichletPartitioner:
    """Label-skew non-IID split (FedGTA/AdaFGL evaluation regime).

    For each class c the M client shares are drawn from Dir(α·1_M) and the
    class's nodes are dealt out by largest-remainder rounding of those
    shares. ``alpha`` interpolates between IID (α → ∞: every client sees
    every class in near-global proportions) and extreme skew (α → 0: each
    client is dominated by a handful of classes). Per-client label entropy
    is monotone in α (property-checked in ``tests/test_partitioners.py``).
    """

    alpha: float = 1.0

    def assign(self, graph: Graph, num_clients: int, *, seed: int = 0) -> np.ndarray:
        if self.alpha <= 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")
        rng = np.random.default_rng(seed)
        y = np.asarray(graph.y)
        assign = np.zeros(graph.num_nodes, dtype=np.int32)
        for c in np.unique(y):
            idx = rng.permutation(np.where(y == c)[0])
            raw = rng.dirichlet(np.full(num_clients, self.alpha)) * len(idx)
            counts = np.floor(raw).astype(np.int64)
            short = len(idx) - int(counts.sum())
            if short:
                counts[np.argsort(-(raw - counts))[:short]] += 1
            for ci, part in enumerate(np.split(idx, np.cumsum(counts)[:-1])):
                assign[part] = ci
        return _fill_empty_clients(assign, num_clients, rng)


@dataclasses.dataclass(frozen=True)
class DegreeSkewPartitioner:
    """Topology heterogeneity: contiguous slices of the degree ordering.

    Client 0 receives the sparsest nodes, client M-1 the hubs — equal client
    sizes but very different local topologies (the AdaFGL axis), so the
    value of imputed cross-subgraph links differs sharply across clients.
    Ties are broken by a small seeded jitter so the split is deterministic
    per seed but not an artifact of node numbering.
    """

    def assign(self, graph: Graph, num_clients: int, *, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        n = graph.num_nodes
        deg = np.zeros(n, dtype=np.float64)
        np.add.at(deg, np.asarray(graph.senders), 1.0)
        np.add.at(deg, np.asarray(graph.receivers), 1.0)
        order = np.argsort(deg + rng.uniform(0.0, 0.5, n), kind="stable")
        assign = np.empty(n, dtype=np.int32)
        bounds = (np.arange(1, num_clients) * n) // num_clients
        for ci, chunk in enumerate(np.split(order, bounds)):
            assign[chunk] = ci
        return assign


@dataclasses.dataclass(frozen=True)
class RandomEdgeCutPartitioner:
    """Uniform random node assignment — the random edge-cut baseline.

    Every edge lands cross-client with probability 1 - 1/M, maximizing
    |ΔE| for a given M; the floor any structure-aware split must beat.
    """

    def assign(self, graph: Graph, num_clients: int, *, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        assign = rng.integers(0, num_clients, size=graph.num_nodes).astype(np.int32)
        return _fill_empty_clients(assign, num_clients, rng)


#: CLI / registry names -> strategy class (``fgl_train --partitioner``).
PARTITIONERS = {
    "label_prop": LabelPropagationPartitioner,
    "dirichlet": DirichletPartitioner,
    "degree": DegreeSkewPartitioner,
    "random": RandomEdgeCutPartitioner,
}


def make_partitioner(name: str, **kw) -> Partitioner:
    """Build the named partitioner; keys its dataclass does not declare are
    dropped, so callers can pass e.g. ``alpha=`` unconditionally."""
    try:
        cls = PARTITIONERS[name]
    except KeyError:
        raise KeyError(f"unknown partitioner {name!r}; "
                       f"available: {', '.join(sorted(PARTITIONERS))}") from None
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in kw.items() if k in fields})


def label_skew_entropy(assign: np.ndarray, y, num_clients: int) -> np.ndarray:
    """[M] per-client label-distribution entropy (nats) — the skew diagnostic.

    log(c) means a client sees the classes uniformly (IID end); 0 means a
    single class. ``benchmarks/bench_heterogeneity.py`` reports the mean.
    """
    y = np.asarray(y)
    ent = np.zeros(num_clients, dtype=np.float64)
    for ci in range(num_clients):
        counts = np.bincount(y[assign == ci])
        p = counts[counts > 0] / max(counts.sum(), 1)
        ent[ci] = float(-(p * np.log(p)).sum())
    return ent


def count_missing_links(graph: Graph, assign: np.ndarray) -> int:
    """|ΔE|: links deleted because their endpoints land on different clients."""
    s = np.asarray(graph.senders)
    r = np.asarray(graph.receivers)
    return int(np.sum(assign[s] != assign[r]))


def partition_graph(graph: Graph, num_clients: int, *, label_ratio: float = 0.3,
                    test_ratio: float = 0.2, aug_max: int = 16,
                    seed: int = 0,
                    partitioner: Union[Partitioner, str, None] = None
                    ) -> Tuple[ClientBatch, np.ndarray]:
    """Split ``graph`` into M disjoint padded client subgraphs.

    A thin dispatcher: the :class:`Partitioner` strategy (default
    ``label_prop``; a string resolves through :func:`make_partitioner`)
    produces the node->client ``assign``, and this function materializes the
    padded :class:`ClientBatch` — identically for every strategy. Cross-
    client edges are DELETED (they are the missing links of Sec. III-A);
    their count is reported by :func:`count_missing_links`.

    Returns (client_batch, assign).
    """
    if partitioner is None:
        partitioner = LabelPropagationPartitioner()
    elif isinstance(partitioner, str):
        partitioner = make_partitioner(partitioner)
    rng = np.random.default_rng(seed)
    assign = np.asarray(partitioner.assign(graph, num_clients, seed=seed),
                        dtype=np.int32)

    sizes = np.bincount(assign, minlength=num_clients)
    n_local_max = int(sizes.max())
    n_pad = n_local_max + aug_max
    d = graph.feature_dim
    m = num_clients

    x = np.zeros((m, n_pad, d), dtype=np.float32)
    adj = np.zeros((m, n_pad, n_pad), dtype=np.float32)
    y = -np.ones((m, n_pad), dtype=np.int32)
    node_mask = np.zeros((m, n_pad), dtype=np.float32)
    train_mask = np.zeros((m, n_pad), dtype=np.float32)
    test_mask = np.zeros((m, n_pad), dtype=np.float32)
    global_id = -np.ones((m, n_pad), dtype=np.int32)

    s = np.asarray(graph.senders)
    r = np.asarray(graph.receivers)
    gx = np.asarray(graph.x)
    gy = np.asarray(graph.y)

    for ci in range(m):
        nodes = np.where(assign == ci)[0]
        k = len(nodes)
        local_index = {int(g): i for i, g in enumerate(nodes)}
        x[ci, :k] = gx[nodes]
        y[ci, :k] = gy[nodes]
        node_mask[ci, :k] = 1.0
        global_id[ci, :k] = nodes
        # Intra-client edges only.
        keep = (assign[s] == ci) & (assign[r] == ci)
        for u, v in zip(s[keep], r[keep]):
            iu, iv = local_index[int(u)], local_index[int(v)]
            adj[ci, iu, iv] = 1.0
            adj[ci, iv, iu] = 1.0
        # Label split: label_ratio train, test_ratio test (disjoint).
        perm = rng.permutation(k)
        n_tr = max(1, int(round(label_ratio * k)))
        n_te = max(1, int(round(test_ratio * k)))
        train_mask[ci, perm[:n_tr]] = 1.0
        test_mask[ci, perm[n_tr:n_tr + n_te]] = 1.0

    batch = ClientBatch(x=x, adj=adj, y=y, node_mask=node_mask,
                        train_mask=train_mask, test_mask=test_mask,
                        global_id=global_id, num_classes=graph.num_classes,
                        aug_max=aug_max)
    return batch, assign


def group_clients_by_server(num_clients: int, num_servers: int) -> np.ndarray:
    """[M] -> server id; contiguous balanced grouping (clients talk to nearest server)."""
    return (np.arange(num_clients) * num_servers // num_clients).astype(np.int32)


def ring_adjacency(num_servers: int, *, self_loop: bool = True) -> np.ndarray:
    """Edge-layer topology A of Sec. III-E (paper testbed uses a ring).

    The single source of ring structure for the server layer:
    :class:`repro.core.strategies.RingTopology` builds its ``TopologyLayout``
    from this matrix, and :func:`repro.core.gossip.block_ring_gossip`'s
    implicit left/right-neighbor schedule realizes the SAME adjacency with
    ``collective_permute`` instead of a materialized [N, N] matrix —
    ``tests/test_gossip.py::TestRingSingleSource`` pins the two against each
    other for N ≥ 3 (at N = 2 a true ring doubles its single edge; callers
    route N ≤ 2 through the adjacency path).
    """
    a = np.zeros((num_servers, num_servers), dtype=np.float32)
    if num_servers == 1:
        return np.ones((1, 1), dtype=np.float32)
    for j in range(num_servers):
        a[j, (j - 1) % num_servers] = 1.0
        a[j, (j + 1) % num_servers] = 1.0
        if self_loop:
            a[j, j] = 1.0
    return a
