"""Adaptive graph imputation generator (Sec. III-C).

Pipeline, run at the edge server every K edge-client communications:

1. Fuse client embeddings H^(j,i) (softmax-space GNN outputs) into the
   globally-shared information H^j (Eq. 9).
2. Build the global similarity topology A̅ = H Hᵀ and keep, per node, the
   top-k most similar *cross-subgraph* nodes as imputed links E̅.
3. An autoencoder maps a random noise matrix S through encoder f ({c,16,d})
   to imputed node features X̅ = f(S) and decoder h ({d,16,c}) back to the
   reconstruction H̄ = h(f(S)) (Eq. 10), trained adversarially against the
   versatile assessor (assessor.py).

The gram-matrix step is the FGL-side compute hot spot (n² in the number of
nodes an edge server covers); ``kernel_impl="pallas"`` routes it through the
fused masked top-k ``sim_topk`` Pallas kernel (``kernel_impl="pallas_interpret"``
runs the same kernel in interpret mode for CPU validation).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.gnn import _glorot

PyTree = Dict


# ---------------------------------------------------------------------------
# Eq. (9): fusion of client embeddings.
# ---------------------------------------------------------------------------

def fuse_embeddings(client_h: jnp.ndarray, node_mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[M, n_pad, c] client embeddings -> flat global H^j  [M*n_pad, c].

    Returns (h_global, flat_mask). Padded slots keep mask 0 so downstream
    similarity/top-k ignores them; flattening keeps a static shape.
    """
    m, n_pad, c = client_h.shape
    return client_h.reshape(m * n_pad, c), node_mask.reshape(m * n_pad)


def client_of_flat(num_clients: int, n_pad: int) -> jnp.ndarray:
    """[M*n_pad] owning-client id of each flattened global slot."""
    return jnp.repeat(jnp.arange(num_clients, dtype=jnp.int32), n_pad)


# ---------------------------------------------------------------------------
# Similarity topology A̅ = H Hᵀ + cross-subgraph top-k links.
# ---------------------------------------------------------------------------

KERNEL_IMPLS = ("reference", "pallas", "pallas_interpret")


def similarity_topk(h: jnp.ndarray, flat_mask: jnp.ndarray, client_ids: jnp.ndarray,
                    k: int, *, kernel_impl: str = "reference", block: int = 256,
                    target_mask: jnp.ndarray = None, mesh=None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k most-similar cross-subgraph nodes per node.

    Thin dispatcher over paths that never materialize the full n×n gram
    matrix:

    - ``"reference"``: jnp row blocks — each [block, n] slab is masked and
      reduced with ``jax.lax.top_k`` immediately. The same-client mask is
      likewise built per row block ([block, n]), never as a full [n, n]
      intermediate (pinned by a jaxpr regression in tests/test_ring_topk.py).
    - ``"pallas"`` / ``"pallas_interpret"``: the fused masked top-k kernel
      (kernels/sim_topk.py) — gram tile, same-client + target masking, and a
      running top-k all stay in VMEM across column tiles.
    - ``mesh is not None``: the candidate-sharded ring driver
      (core/ring_topk.py) — candidate slabs rotate around the mesh ring via
      collective_permute and each device streams them into its partial top-k,
      which after ``mesh.size`` steps IS the exact global answer (bit-
      identical to ``"reference"``). ``h``/masks may carry a leading batch
      axis here (one element per edge server), which rides along replicated.

    ``flat_mask`` marks valid *source* rows; ``target_mask`` (defaults to
    ``flat_mask``) marks slots allowed as link targets — the engine restricts
    it to real local slots so imputed aug nodes are never re-linked.

    Returns (scores [.., n, k], idx [.., n, k]); rows with mask 0 and
    unfilled candidate slots get idx -1 / score 0.
    """
    if target_mask is None:
        target_mask = flat_mask
    n = h.shape[-2]
    if mesh is not None:
        from repro.core.ring_topk import ring_similarity_topk
        scores, idx = ring_similarity_topk(h, client_ids, target_mask, k,
                                           mesh=mesh)
    elif kernel_impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        scores, idx = kops.sim_topk(h, client_ids, target_mask, k,
                                    block_m=block,
                                    interpret=(kernel_impl == "pallas_interpret"))
    elif kernel_impl == "reference":
        num_blocks = (n + block - 1) // block
        pad_n = num_blocks * block
        h_pad = jnp.pad(h, ((0, pad_n - n), (0, 0)))
        cid_pad = jnp.pad(client_ids, (0, pad_n - n))

        def one_block(bi):
            rows = jax.lax.dynamic_slice_in_dim(h_pad, bi * block, block, axis=0)
            gram = rows @ h.T
            # Same-client mask per [block, n] slab — never the [n, n] matrix.
            rcid = jax.lax.dynamic_slice_in_dim(cid_pad, bi * block, block)
            same = rcid[:, None] == client_ids[None, :]
            gram = jnp.where(same, -jnp.inf, gram)           # cross-subgraph only
            gram = jnp.where(target_mask[None, :] > 0, gram, -jnp.inf)
            return jax.lax.top_k(gram, k)

        scores, idx = jax.lax.map(one_block, jnp.arange(num_blocks))
        scores = scores.reshape(pad_n, k)[:n]
        idx = idx.reshape(pad_n, k)[:n]
    else:
        raise ValueError(f"unknown kernel_impl {kernel_impl!r}; "
                         f"expected one of {KERNEL_IMPLS}")
    valid = (flat_mask[..., None] > 0) & jnp.isfinite(scores)
    idx = jnp.where(valid, idx.astype(jnp.int32), -1)
    scores = jnp.where(valid, scores, 0.0)
    return scores, idx


def local_slot_mask(num_clients: int, n_pad: int, n_local: int) -> jnp.ndarray:
    """[num_clients*n_pad] mask of *real local* slots (aug slots excluded).

    Link targets must come from this set: the graphic patcher sets
    ``node_mask=1`` on augmented slots it fills, so masking targets with the
    node mask alone would let later fixing rounds pick synthetic nodes as
    cross-subgraph link targets (and re-impute already-imputed features).
    """
    local = (jnp.arange(n_pad) < n_local).astype(jnp.float32)
    return jnp.tile(local, num_clients)


# ---------------------------------------------------------------------------
# Eq. (10): autoencoder S -> X̅ = f(S) -> H̄ = h(X̅).
# ---------------------------------------------------------------------------

def init_autoencoder(key, c: int, d: int, hidden: int = 16) -> PyTree:
    ks = jax.random.split(key, 4)
    return {
        "enc": [
            {"w": _glorot(ks[0], (c, hidden)), "b": jnp.zeros((hidden,))},
            {"w": _glorot(ks[1], (hidden, d)), "b": jnp.zeros((d,))},
        ],
        "dec": [
            {"w": _glorot(ks[2], (d, hidden)), "b": jnp.zeros((hidden,))},
            {"w": _glorot(ks[3], (hidden, c)), "b": jnp.zeros((c,))},
        ],
    }


def init_stacked_autoencoder(key, n_servers: int, c: int, d: int,
                             hidden: int = 16) -> PyTree:
    """N per-server autoencoders as one pytree with a leading [N] axis.

    Server j's weights match ``init_autoencoder(fold_in(key, j), ...)`` so the
    stacked layout is bit-identical to the seed's per-server list.
    """
    keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(jnp.arange(n_servers))
    return jax.vmap(lambda k: init_autoencoder(k, c, d, hidden))(keys)


def encode(params: PyTree, s: jnp.ndarray) -> jnp.ndarray:
    """X̅ = f(S): imputed potential features."""
    h = jax.nn.relu(s @ params["enc"][0]["w"] + params["enc"][0]["b"])
    return h @ params["enc"][1]["w"] + params["enc"][1]["b"]


def decode(params: PyTree, x_bar: jnp.ndarray) -> jnp.ndarray:
    """H̄ = h(X̅); softmax last layer (paper: Softmax activation in the AE head)."""
    h = jax.nn.relu(x_bar @ params["dec"][0]["w"] + params["dec"][0]["b"])
    logits = h @ params["dec"][1]["w"] + params["dec"][1]["b"]
    return jax.nn.softmax(logits, axis=-1)


def reconstruct(params: PyTree, s: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x_bar = encode(params, s)
    return x_bar, decode(params, x_bar)


def sample_noise(key, n: int, c: int) -> jnp.ndarray:
    """Random noise S (privacy: the AE never sees raw features)."""
    return jax.random.normal(key, (n, c), dtype=jnp.float32)
