"""SpreadFGL / FedGL as strategy compositions (Sec. III-B and III-E).

Thin builders over the shared :class:`~repro.core.fedgl.FGLTrainer` engine,
wired exactly as the paper's experiment section configures them and
registered in :mod:`repro.core.registry`:

- ``make_fedgl`` (``"FedGL"``): star topology (one edge server covering all
  clients), FedAvg aggregation, SpreadFGL generator round.
- ``make_spreadfgl`` (``"SpreadFGL"``): N edge servers (3 in the paper's
  testbed) on a ring — or any custom adjacency — Eq. 16 neighbor
  aggregation, Eq. 15 trace regularizer, SpreadFGL generator round.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.registry import register
from repro.core.types import ClientBatch, FGLConfig


@register("FedGL")
def make_fedgl(cfg: FGLConfig, batch: ClientBatch, **kw) -> FGLTrainer:
    return FGLTrainer(cfg, batch, topology=S.StarTopology(),
                      aggregator=S.FedAvgAggregator(),
                      imputation=S.SpreadImputation(), **kw)


@register("SpreadFGL")
def make_spreadfgl(cfg: FGLConfig, batch: ClientBatch, *, num_servers: int = 3,
                   adjacency: Optional[np.ndarray] = None, **kw) -> FGLTrainer:
    if adjacency is not None:
        if adjacency.shape[0] != num_servers:
            raise ValueError(f"adjacency is {adjacency.shape[0]}x"
                             f"{adjacency.shape[1]} but num_servers={num_servers}")
        topology = S.CustomTopology(adjacency)
    else:
        topology = S.RingTopology(num_servers)
    return FGLTrainer(cfg, batch, topology=topology,
                      aggregator=S.NeighborAggregator(),
                      imputation=S.SpreadImputation(), **kw)
