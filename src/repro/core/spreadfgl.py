"""SpreadFGL / FedGL facades (Sec. III-B and III-E).

Thin constructors over the shared :class:`~repro.core.fedgl.FGLTrainer` engine,
wired exactly as the paper's experiment section configures them:

- ``make_fedgl``: one edge server covering all clients, FedAvg aggregation.
- ``make_spreadfgl``: N edge servers (3 in the paper's testbed) on a ring
  topology, Eq. 15 trace regularizer, Eq. 16 neighbor aggregation.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.fedgl import FGLTrainer
from repro.core.partition import group_clients_by_server, ring_adjacency
from repro.core.types import ClientBatch, FGLConfig


def make_fedgl(cfg: FGLConfig, batch: ClientBatch, **kw) -> FGLTrainer:
    m = batch.num_clients
    adj = np.ones((1, 1), dtype=np.float32)
    server_of_client = np.zeros(m, dtype=np.int32)
    cfg = _with_servers(cfg, 1, m)
    return FGLTrainer(cfg, batch, adj, server_of_client, **kw)


def make_spreadfgl(cfg: FGLConfig, batch: ClientBatch, *, num_servers: int = 3,
                   adjacency: Optional[np.ndarray] = None, **kw) -> FGLTrainer:
    m = batch.num_clients
    if m % num_servers:
        raise ValueError(f"M={m} must divide across N={num_servers} servers")
    adj = adjacency if adjacency is not None else ring_adjacency(num_servers)
    server_of_client = group_clients_by_server(m, num_servers)
    cfg = _with_servers(cfg, num_servers, m // num_servers)
    return FGLTrainer(cfg, batch, adj, server_of_client, **kw)


def _with_servers(cfg: FGLConfig, n: int, m_per: int) -> FGLConfig:
    import dataclasses
    return dataclasses.replace(cfg, num_edge_servers=n, clients_per_server=m_per)
