"""SpreadFGL / FedGL as strategy compositions (Sec. III-B and III-E).

Thin builders over the shared :class:`~repro.core.fedgl.FGLTrainer` engine,
wired exactly as the paper's experiment section configures them and
registered in :mod:`repro.core.registry`:

- ``make_fedgl`` (``"FedGL"``): star topology (one edge server covering all
  clients), FedAvg aggregation, SpreadFGL generator round.
- ``make_spreadfgl`` (``"SpreadFGL"``): N edge servers (3 in the paper's
  testbed) on a ring — or any custom adjacency — Eq. 16 neighbor
  aggregation, Eq. 15 trace regularizer, SpreadFGL generator round.
- ``make_spreadfgl_gossip`` (``"spreadfgl_gossip"``): same composition but
  with :class:`~repro.core.strategies.GossipAggregator` — cross-server
  parameter exchange only every K rounds (``cfg.gossip_every`` /
  ``gossip_every=``), executed on the edge mesh when one is supplied. K=1
  reproduces ``"SpreadFGL"`` exactly (see ``tests/test_gossip.py``).
- ``make_spreadfgl_async`` (``"spreadfgl_async"``): same layout but with
  :class:`~repro.core.strategies.AsyncAggregator` — FedBuff-style buffered
  aggregation with straggler delays, mid-round dropouts, and staleness
  discounting (``cfg.async_buffer`` / ``async_buffer=``). B = M with zero
  delays reproduces ``"FedGL"`` / ``"SpreadFGL"``-per-server FedAvg
  bit-identically (see ``tests/test_async_agg.py``).

All three accept ``sim_mesh=`` — a jax Mesh to shard the imputation
similarity search's CANDIDATE axis over (``--sim-shard`` in the launchers;
:mod:`repro.core.ring_topk`). Orthogonal to ``edge_mesh``, which places the
[N] server axis; ``launch/fgl_train.py`` reuses one mesh for both when both
flags are set.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.registry import register
from repro.core.types import ClientBatch, FGLConfig


@register("FedGL")
def make_fedgl(cfg: FGLConfig, batch: ClientBatch, *, sim_mesh=None,
               **kw) -> FGLTrainer:
    return FGLTrainer(cfg, batch, topology=S.StarTopology(),
                      aggregator=S.FedAvgAggregator(),
                      imputation=S.SpreadImputation(sim_mesh=sim_mesh), **kw)


@register("SpreadFGL")
def make_spreadfgl(cfg: FGLConfig, batch: ClientBatch, *, num_servers: int = 3,
                   adjacency: Optional[np.ndarray] = None, sim_mesh=None,
                   **kw) -> FGLTrainer:
    if adjacency is not None:
        if adjacency.shape[0] != num_servers:
            raise ValueError(f"adjacency is {adjacency.shape[0]}x"
                             f"{adjacency.shape[1]} but num_servers={num_servers}")
        topology = S.CustomTopology(adjacency)
    else:
        topology = S.RingTopology(num_servers)
    return FGLTrainer(cfg, batch, topology=topology,
                      aggregator=S.NeighborAggregator(),
                      imputation=S.SpreadImputation(sim_mesh=sim_mesh), **kw)


@register("spreadfgl_gossip")
def make_spreadfgl_gossip(cfg: FGLConfig, batch: ClientBatch, *,
                          num_servers: int = 3, gossip_every: Optional[int] = None,
                          adjacency: Optional[np.ndarray] = None,
                          edge_mesh=None, sim_mesh=None, **kw) -> FGLTrainer:
    """SpreadFGL with decentralized gossip training at the edge (Sec. III-E).

    Identical to ``"SpreadFGL"`` except aggregation: servers FedAvg their own
    clients every round but exchange parameters with topology neighbors only
    every ``gossip_every`` rounds (default ``cfg.gossip_every``), via
    collective_permute on the edge mesh when ``edge_mesh`` is given. With
    ``gossip_every=1`` the histories match ``"SpreadFGL"`` to float32
    tolerance (pinned in ``tests/test_gossip.py``).
    """
    every = int(gossip_every) if gossip_every is not None else cfg.gossip_every
    if adjacency is not None:
        if adjacency.shape[0] != num_servers:
            raise ValueError(f"adjacency is {adjacency.shape[0]}x"
                             f"{adjacency.shape[1]} but num_servers={num_servers}")
        topology: S.Topology = S.CustomTopology(adjacency)
        kind = "adjacency"
    else:
        topology = S.RingTopology(num_servers)
        kind = "ring"
    aggregator = S.GossipAggregator(topology=kind, every_k=every,
                                    mesh=edge_mesh)
    return FGLTrainer(cfg, batch, topology=topology, aggregator=aggregator,
                      imputation=S.SpreadImputation(sim_mesh=sim_mesh),
                      edge_mesh=edge_mesh, **kw)


@register("spreadfgl_async")
def make_spreadfgl_async(cfg: FGLConfig, batch: ClientBatch, *,
                         num_servers: int = 3,
                         async_buffer: Optional[int] = None,
                         adjacency: Optional[np.ndarray] = None,
                         sim_mesh=None, **kw) -> FGLTrainer:
    """SpreadFGL with FedBuff-style async straggler-tolerant aggregation.

    Same edge layout and generator round as ``"SpreadFGL"`` (star when
    ``num_servers == 1``, i.e. async FedGL), but aggregation is the buffered
    :class:`~repro.core.strategies.AsyncAggregator`: client updates arrive
    with per-round delays drawn from ``cfg.delay_dist``, drop out mid-round
    with probability ``cfg.dropout_rate``, and each edge server flushes a
    staleness-discounted mean only once ``async_buffer`` (default
    ``cfg.async_buffer``) updates are buffered. The schedule is a pure
    function of ``(cfg.seed, round)`` — save/resume mid-buffer is exact.
    With B = M, zero delays, and no dropouts the histories reproduce the
    synchronous FedAvg compositions bit-identically
    (``tests/test_async_agg.py``).
    """
    buffer = int(async_buffer) if async_buffer is not None else cfg.async_buffer
    if buffer < 1:
        raise ValueError(f"spreadfgl_async needs async_buffer >= 1, "
                         f"got {buffer} (set cfg.async_buffer or pass "
                         f"async_buffer=)")
    if buffer > batch.num_clients:
        raise ValueError(f"async_buffer={buffer} can never fill: the buffer "
                         f"holds at most one update per client "
                         f"(M={batch.num_clients})")
    if num_servers == 1:
        topology: S.Topology = S.StarTopology()
    elif adjacency is not None:
        if adjacency.shape[0] != num_servers:
            raise ValueError(f"adjacency is {adjacency.shape[0]}x"
                             f"{adjacency.shape[1]} but num_servers={num_servers}")
        topology = S.CustomTopology(adjacency)
    else:
        topology = S.RingTopology(num_servers)
    aggregator = S.AsyncAggregator(
        buffer_size=buffer, delay_dist=cfg.delay_dist,
        dropout_rate=cfg.dropout_rate, max_delay=cfg.async_max_delay,
        seed=cfg.seed)
    return FGLTrainer(cfg, batch, topology=topology, aggregator=aggregator,
                      imputation=S.SpreadImputation(sim_mesh=sim_mesh), **kw)
