"""Versatile assessor + negative sampling (Sec. III-C/D, Eq. 11-14).

The assessor is a GAN-style discriminator: an MLP {c, 128, 16, 1} with ReLU
hidden layers and a sigmoid head that scores a softmax-space node vector. It is
trained to score the real globally-shared information H high and the
autoencoder reconstruction H̄ low (Eq. 13); the autoencoder is trained
adversarially to push its reconstruction's score up, plus a masked L2
reconstruction term on the negative-sampled attributes (Eq. 14).

Negative sampling: e_u[i] = 1 iff h_u[i] > theta (theta = 1/c). Attributes with
e=1 enter the adversarial terms, attributes with e=0 are zero-regularized via
the reconstruction term — both nets "spotlight" discriminative class mass.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.gnn import _glorot

PyTree = Dict
_EPS = 1e-6


def init_assessor(key, c: int, hidden: Sequence[int] = (128, 16)) -> PyTree:
    dims = (c,) + tuple(hidden) + (1,)
    layers = []
    for i, k in enumerate(jax.random.split(key, len(dims) - 1)):
        layers.append({"w": _glorot(k, (dims[i], dims[i + 1])),
                       "b": jnp.zeros((dims[i + 1],))})
    return {"layers": layers}


def init_stacked_assessor(key, n_servers: int, c: int,
                          hidden: Sequence[int] = (128, 16)) -> PyTree:
    """N per-server assessors as one pytree with a leading [N] axis.

    Server j's weights match ``init_assessor(fold_in(key, j), ...)`` so the
    stacked layout is bit-identical to the seed's per-server list.
    """
    keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(jnp.arange(n_servers))
    return jax.vmap(lambda k: init_assessor(k, c, hidden))(keys)


def apply_assessor(params: PyTree, h: jnp.ndarray) -> jnp.ndarray:
    """Score in (0,1) per node: [n, c] -> [n]."""
    z = h
    n_layers = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        z = z @ layer["w"] + layer["b"]
        if li < n_layers - 1:
            z = jax.nn.relu(z)
    return jax.nn.sigmoid(z[..., 0])


def negative_mask(h_real: jnp.ndarray, theta: float) -> jnp.ndarray:
    """e_u (Eq. 13): 1 where the attribute exceeds the threshold theta."""
    return (h_real > theta).astype(h_real.dtype)


def assessor_loss(params_as: PyTree, h_real: jnp.ndarray, h_fake: jnp.ndarray,
                  e: jnp.ndarray, node_mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (13). Minimized in the assessor's parameters.

    L_AS = mean_u [ log(1 - Assor(h_u ⊙ e_u)) + log(Assor(h̄_u ⊙ e_u)) ]
    (minimizing drives Assor(real)→1 and Assor(fake)→0).
    """
    s_real = apply_assessor(params_as, h_real * e)
    s_fake = apply_assessor(params_as, h_fake * e)
    per_node = jnp.log1p(-s_real + _EPS) + jnp.log(s_fake + _EPS)
    denom = jnp.maximum(jnp.sum(node_mask), 1.0)
    return jnp.sum(per_node * node_mask) / denom


def autoencoder_loss(params_ae: PyTree, params_as: PyTree, s_noise: jnp.ndarray,
                     h_real: jnp.ndarray, e: jnp.ndarray,
                     node_mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (14). Minimized in the autoencoder's parameters (assessor frozen).

    L_AE = mean_u [ log(1 - Assor(h̄_u ⊙ e_u))
                    + || h_u ⊙ (1-e_u) - h̄_u ⊙ (1-e_u) ||² ]
    """
    from repro.core import imputation
    _, h_fake = imputation.reconstruct(params_ae, s_noise)
    s_fake = apply_assessor(params_as, h_fake * e)
    adv = jnp.log1p(-s_fake + _EPS)
    neg = (h_real - h_fake) * (1.0 - e)
    rec = jnp.sum(neg * neg, axis=-1)
    per_node = adv + rec
    denom = jnp.maximum(jnp.sum(node_mask), 1.0)
    return jnp.sum(per_node * node_mask) / denom


def autoencoder_loss_plain(params_ae: PyTree, params_as: PyTree, s_noise: jnp.ndarray,
                           node_mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (11): ablation variant WITHOUT negative sampling (Fig. 7 'w/o NS')."""
    from repro.core import imputation
    _, h_fake = imputation.reconstruct(params_ae, s_noise)
    s_fake = apply_assessor(params_as, h_fake)
    denom = jnp.maximum(jnp.sum(node_mask), 1.0)
    return jnp.sum(jnp.log1p(-s_fake + _EPS) * node_mask) / denom


def assessor_loss_plain(params_as: PyTree, h_real: jnp.ndarray, h_fake: jnp.ndarray,
                        node_mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (12): ablation variant WITHOUT negative sampling."""
    s_real = apply_assessor(params_as, h_real)
    s_fake = apply_assessor(params_as, h_fake)
    per_node = jnp.log1p(-s_real + _EPS) + jnp.log(s_fake + _EPS)
    denom = jnp.maximum(jnp.sum(node_mask), 1.0)
    return jnp.sum(per_node * node_mask) / denom
