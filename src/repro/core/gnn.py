"""GNN node classifiers on dense padded adjacency (Sec. II-A, Eq. 1-3).

Functional init/apply modules (no flax offline). All ops are masked so padded
node slots neither contribute to nor receive messages. The GraphSAGE layer with
the GCN (mean) aggregator is the paper's local node classifier F_i^j.

The neighbor aggregation ``A_norm @ h`` is the per-client compute hot spot; on
TPU it is served by the ``sage_aggregate`` Pallas kernel (kernels/), selected
via the engine-wide ``kernel_impl`` knob (``FGLConfig.kernel_impl`` /
``fgl_train --impl``), which reaches this module as the ``impl=`` argument.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

PyTree = Dict


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, minval=-lim, maxval=lim, dtype=jnp.float32)


def normalize_adjacency(adj: jnp.ndarray, node_mask: jnp.ndarray) -> jnp.ndarray:
    """Row-normalized masked adjacency (GCN mean aggregator), no self loop."""
    mask2d = node_mask[..., :, None] * node_mask[..., None, :]
    a = adj * mask2d
    deg = jnp.sum(a, axis=-1, keepdims=True)
    return a / jnp.maximum(deg, 1.0)


def aggregate(a_norm: jnp.ndarray, h: jnp.ndarray, impl: str = "reference") -> jnp.ndarray:
    """Neighbor mean aggregation AGG(h_v) = A_norm @ h."""
    if impl == "reference":
        return a_norm @ h
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.sage_aggregate(a_norm, h, interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown aggregate impl {impl!r}")


# ---------------------------------------------------------------------------
# GraphSAGE (GCN aggregator), Eq. (3): h' = sigma([h || AGG(h)] W)
# ---------------------------------------------------------------------------

def init_sage(key, dims: Sequence[int]) -> PyTree:
    """dims = [d_in, hidden, ..., c]; each layer has self + neighbor weights."""
    params: List[Dict] = []
    keys = jax.random.split(key, len(dims) - 1)
    for i, k in enumerate(keys):
        k1, k2 = jax.random.split(k)
        params.append({
            "w_self": _glorot(k1, (dims[i], dims[i + 1])),
            "w_nbr": _glorot(k2, (dims[i], dims[i + 1])),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return {"layers": params}


def apply_sage(params: PyTree, x, adj, node_mask, *, impl: str = "reference"):
    """Returns per-node logits [n, c]. Masked: padded rows output zeros."""
    a_norm = normalize_adjacency(adj, node_mask)
    h = x * node_mask[..., None]
    n_layers = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        agg = aggregate(a_norm, h, impl)
        # [h || agg] W  ==  h W_self + agg W_nbr
        h = h @ layer["w_self"] + agg @ layer["w_nbr"] + layer["b"]
        if li < n_layers - 1:
            h = jax.nn.relu(h)
        h = h * node_mask[..., None]
    return h


# ---------------------------------------------------------------------------
# GCN, Eq. (1)
# ---------------------------------------------------------------------------

def init_gcn(key, dims: Sequence[int]) -> PyTree:
    params = []
    for i, k in enumerate(jax.random.split(key, len(dims) - 1)):
        params.append({"w": _glorot(k, (dims[i], dims[i + 1])),
                       "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return {"layers": params}


def apply_gcn(params: PyTree, x, adj, node_mask, *, impl: str = "reference"):
    # Self loops then symmetric-ish (row) normalization.
    eye = jnp.eye(adj.shape[-1], dtype=adj.dtype)
    a_norm = normalize_adjacency(adj + eye, node_mask)
    h = x * node_mask[..., None]
    n_layers = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        h = aggregate(a_norm, h, impl) @ layer["w"] + layer["b"]
        if li < n_layers - 1:
            h = jax.nn.relu(h)
        h = h * node_mask[..., None]
    return h


# ---------------------------------------------------------------------------
# GAT, Eq. (2) (single attention head per layer; enough for ablations)
# ---------------------------------------------------------------------------

def init_gat(key, dims: Sequence[int]) -> PyTree:
    params = []
    for i, k in enumerate(jax.random.split(key, len(dims) - 1)):
        k1, k2, k3 = jax.random.split(k, 3)
        params.append({
            "w": _glorot(k1, (dims[i], dims[i + 1])),
            "a_src": _glorot(k2, (dims[i + 1], 1)),
            "a_dst": _glorot(k3, (dims[i + 1], 1)),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return {"layers": params}


def apply_gat(params: PyTree, x, adj, node_mask, *, impl: str = "reference"):
    del impl
    mask2d = node_mask[..., :, None] * node_mask[..., None, :]
    eye = jnp.eye(adj.shape[-1], dtype=adj.dtype)
    a = (adj + eye) * mask2d
    h = x * node_mask[..., None]
    n_layers = len(params["layers"])
    for li, layer in enumerate(params["layers"]):
        z = h @ layer["w"]
        e = z @ layer["a_src"] + jnp.swapaxes(z @ layer["a_dst"], -1, -2)
        e = jax.nn.leaky_relu(e, 0.2)
        e = jnp.where(a > 0, e, -1e9)
        att = jax.nn.softmax(e, axis=-1)
        att = jnp.where(a > 0, att, 0.0)
        h = att @ z + layer["b"]
        if li < n_layers - 1:
            h = jax.nn.elu(h)
        h = h * node_mask[..., None]
    return h


KINDS = {
    "sage": (init_sage, apply_sage),
    "gcn": (init_gcn, apply_gcn),
    "gat": (init_gat, apply_gat),
}


def init_classifier(key, kind: str, dims: Sequence[int]) -> PyTree:
    return KINDS[kind][0](key, dims)


def apply_classifier(params: PyTree, kind: str, x, adj, node_mask, *,
                     impl: str = "reference"):
    return KINDS[kind][1](params, x, adj, node_mask, impl=impl)
