"""SpreadFGL's neighbor aggregation (Eq. 16) on the TPU mesh.

The paper replaces a single FedAvg point with edge servers that average
parameters only with their ring neighbors (Sec. III-E). On a multi-pod mesh the
analogue: each pod is an "edge server"; instead of an all-reduce over the
``pod`` axis every step (classic data parallelism = classic FGL's FedAvg),
parameters are exchanged with the two ring neighbors via collective_permute
every K steps. Cross-pod ICI bytes drop from O(P/step · 2·(P-1)/P · bytes)
to O(2·bytes/K), and the paper's convergence claim (Fig. 8/9) transfers as the
gossip-SGD convergence of the averaged iterates.

These helpers assume they run inside shard_map with ``axis`` a named mesh axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _axis_size(axis: str) -> int:
    """Static size of a named mesh axis.

    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axis)`` is the
    classic idiom and constant-folds to a Python int on every version.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def ring_gossip(params: PyTree, axis: str) -> PyTree:
    """Eq. 16 with a ring adjacency (self + both neighbors, equal weights)."""
    n = _axis_size(axis)
    if n == 1:
        return params
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]
    perm_bwd = [(i, (i - 1) % n) for i in range(n)]

    def avg(p):
        left = jax.lax.ppermute(p, axis, perm_fwd)
        right = jax.lax.ppermute(p, axis, perm_bwd)
        return ((p.astype(jnp.float32) + left.astype(jnp.float32)
                 + right.astype(jnp.float32)) / 3.0).astype(p.dtype)

    return jax.tree.map(avg, params)


def all_average(params: PyTree, axis: str) -> PyTree:
    """Classic FedAvg analogue: full average over the axis (all-reduce)."""
    n = _axis_size(axis)

    def avg(p):
        return (jax.lax.psum(p.astype(jnp.float32), axis) / n).astype(p.dtype)

    return jax.tree.map(avg, params)


def maybe_gossip(params: PyTree, step: jnp.ndarray, axis: str, *,
                 every: int = 1) -> PyTree:
    """Ring-gossip every ``every`` steps (K of Algorithm 1), identity otherwise."""
    if every <= 1:
        return ring_gossip(params, axis)
    gossiped = ring_gossip(params, axis)
    do = (step + 1) % every == 0
    return jax.tree.map(lambda g, p: jnp.where(do, g, p), gossiped, params)
