"""SpreadFGL's load-balanced neighbor aggregation (Eq. 16, Sec. III-E) as gossip.

The paper replaces a single FedAvg point with edge servers that average
parameters only with their topology neighbors (Sec. III-E, Fig. 8/9). Two
deployments of the same math live here:

1. **LM / multi-pod** (``ring_gossip``, ``all_average``, ``maybe_gossip``):
   each pod is an "edge server"; instead of an all-reduce over the ``pod``
   axis every step (classic data parallelism = classic FGL's FedAvg),
   parameters are exchanged with the two ring neighbors via
   ``collective_permute`` every K steps. Cross-pod ICI bytes drop from
   O(2·(P-1)/P · bytes / step) to O(2·bytes/K), and the paper's convergence
   claim (Fig. 8/9) transfers as the gossip-SGD convergence of the averaged
   iterates. These helpers assume they run inside ``shard_map`` with
   ``axis`` a named mesh axis, one server per shard.

2. **FGL / edge mesh** (``block_ring_gossip``, ``adjacency_gossip``): the
   stacked ``[N]`` edge-server axis of the FGL engine, where each mesh shard
   may own a *block* of servers (N need only be a multiple of the mesh
   size). ``strategies.GossipAggregator`` drives these; with ``every_k=1``
   and a ring adjacency they reproduce ``strategies.NeighborAggregator``
   exactly (the allclose regression in ``tests/test_gossip.py`` pins this).

The byte-accounting helpers at the bottom are the single home of the
cross-server traffic math used by ``launch/gossip_dryrun.py`` and
``benchmarks/bench_load_balance.py``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def _axis_size(axis: str) -> int:
    """Static size of a named mesh axis.

    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axis)`` is the
    classic idiom and constant-folds to a Python int on every version.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def ring_gossip(params: PyTree, axis: str) -> PyTree:
    """Eq. 16 with a ring adjacency (self + both neighbors, equal weights)."""
    n = _axis_size(axis)
    if n == 1:
        return params
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]
    perm_bwd = [(i, (i - 1) % n) for i in range(n)]

    def avg(p):
        left = jax.lax.ppermute(p, axis, perm_fwd)
        right = jax.lax.ppermute(p, axis, perm_bwd)
        return ((p.astype(jnp.float32) + left.astype(jnp.float32)
                 + right.astype(jnp.float32)) / 3.0).astype(p.dtype)

    return jax.tree.map(avg, params)


def all_average(params: PyTree, axis: str) -> PyTree:
    """Classic FedAvg analogue: full average over the axis (all-reduce)."""
    n = _axis_size(axis)

    def avg(p):
        return (jax.lax.psum(p.astype(jnp.float32), axis) / n).astype(p.dtype)

    return jax.tree.map(avg, params)


def maybe_gossip(params: PyTree, step: jnp.ndarray, axis: str, *,
                 every: int = 1) -> PyTree:
    """Ring-gossip every ``every`` steps (K of Algorithm 1), identity otherwise."""
    if every <= 1:
        return ring_gossip(params, axis)
    gossiped = ring_gossip(params, axis)
    do = (step + 1) % every == 0
    return jax.tree.map(lambda g, p: jnp.where(do, g, p), gossiped, params)


# ---------------------------------------------------------------------------
# FGL edge-mesh gossip: stacked [N] server axis, block-sharded across devices.
# ---------------------------------------------------------------------------

def block_ring_gossip(params: PyTree, axis: Optional[str] = None) -> PyTree:
    """Eq. 16 ring average over a stacked edge-server axis.

    Every leaf carries servers on its leading axis. With ``axis`` given
    (inside ``shard_map``) the ring spans the full N = axis_size · n_block
    servers: interior neighbors come from the local block, boundary
    neighbors from the adjacent mesh shard via ONE boundary-slice
    ``collective_permute`` each way — so cross-device bytes per exchange are
    2·|W| per shard regardless of how many servers a shard owns. With
    ``axis=None`` the leading axis is the whole ring (single-host / plain
    vmap fallback; numerically identical).

    For a ring adjacency with self-loops (``partition.ring_adjacency``) and
    N ≥ 3 this equals ``strategies.NeighborAggregator`` applied to the
    per-server means: each server becomes (self + left + right) / 3. At
    N = 2 a true ring has the same neighbor on both sides, so the ring
    average (self + 2·other)/3 differs from Eq. 16's (self + other)/2 —
    callers (``GossipAggregator``) route N ≤ 2 through
    :func:`adjacency_gossip` instead.
    """
    def avg(p):
        n_block = p.shape[0]
        f32 = p.astype(jnp.float32)
        if axis is None:
            if n_block == 1:
                return p
            left = jnp.roll(f32, 1, axis=0)
            right = jnp.roll(f32, -1, axis=0)
        else:
            size = _axis_size(axis)
            if size * n_block == 1:
                return p
            fwd = [(i, (i + 1) % size) for i in range(size)]
            bwd = [(i, (i - 1) % size) for i in range(size)]
            from_prev = jax.lax.ppermute(f32[-1:], axis, fwd)
            from_next = jax.lax.ppermute(f32[:1], axis, bwd)
            left = jnp.concatenate([from_prev, f32[:-1]], axis=0)
            right = jnp.concatenate([f32[1:], from_next], axis=0)
        return ((f32 + left + right) / 3.0).astype(p.dtype)

    return jax.tree.map(avg, params)


def adjacency_gossip(params: PyTree, adj: jnp.ndarray,
                     axis: Optional[str] = None) -> PyTree:
    """Eq. 16 with arbitrary server-server weights a_rj (star / custom).

    W_j = Σ_r a_rj W_r / Σ_r a_rj over the stacked server axis — exactly
    ``strategies.NeighborAggregator`` applied to per-server means, for ANY
    adjacency. With ``axis`` given (inside ``shard_map``) the local block is
    ``all_gather``-ed to rebuild the full [N] stack before mixing (a general
    adjacency has no static ``collective_permute`` schedule), then the local
    rows are sliced back out.
    """
    adj = jnp.asarray(adj, jnp.float32)
    den = jnp.sum(adj, axis=0)                               # [N]

    def avg(p):
        f32 = p.astype(jnp.float32)
        n_block = p.shape[0]
        if axis is None:
            full = f32
        else:
            full = jax.lax.all_gather(f32, axis, tiled=True)  # [N, ...]
        num = jnp.einsum("rj,r...->j...", adj, full)
        mixed = num / den.reshape((-1,) + (1,) * (num.ndim - 1))
        if axis is not None:
            start = jax.lax.axis_index(axis) * n_block
            mixed = jax.lax.dynamic_slice_in_dim(mixed, start, n_block, axis=0)
        return mixed.astype(p.dtype)

    return jax.tree.map(avg, params)


# ---------------------------------------------------------------------------
# Cross-server traffic accounting (Sec. III-E load-balancing claim).
# The one home of the byte math: gossip_dryrun and bench_load_balance both
# call these instead of re-deriving ratios inline.
# ---------------------------------------------------------------------------

def ring_gossip_bytes_per_round(param_bytes: int, *, every: int = 1) -> float:
    """Cross-server bytes ONE server sends per round under ring gossip.

    Each exchange sends |W| to both ring neighbors; exchanges happen every
    ``every`` rounds, so the per-round amortized cost is 2·|W|/K.
    """
    return 2.0 * param_bytes / max(every, 1)


def dense_neighbor_bytes_per_round(adj, param_bytes: int, *,
                                   every: int = 1) -> float:
    """Per-server cross-server bytes for dense Eq. 16 neighbor exchange.

    Each server sends |W| to every topology neighbor (off-diagonal nonzero
    of its adjacency row) on each exchange round. The max over servers is
    the Sec. III-E peak load.
    """
    import numpy as np
    a = np.asarray(adj)
    if a.shape[0] == 1:
        return 0.0
    neighbors = ((a != 0).sum(axis=1) - (np.diag(a) != 0)).max()
    return float(neighbors) * param_bytes / max(every, 1)


def allreduce_bytes_per_round(param_bytes: int, n: int) -> float:
    """Per-server bytes of a ring all-reduce over N servers: 2·(N-1)/N·|W|.

    The FedAvg analogue (classic FGL's single aggregation point realized as
    a collective) that gossip replaces.
    """
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * param_bytes


def gossip_allreduce_ratio(allreduce_bytes: float, gossip_bytes: float, *,
                           every: int = 1) -> float:
    """Per-step cross-server byte ratio: amortized gossip vs all-reduce."""
    return (gossip_bytes / max(every, 1)) / max(allreduce_bytes, 1)
