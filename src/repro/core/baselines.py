"""Comparison algorithms of Sec. IV-A.

- LocalFGL: each client trains its classifier alone (no aggregation, no fixing).
- FedAvg-fusion: FedAvg aggregation of client GNNs, no link imputation.
- FedSagePlus: FedAvg + a *local* linear neighbor generator per client
  (Zhang et al., NeurIPS'21 style): a linear predictor maps a node's feature to
  a synthetic neighbor feature, trained on the client's own held-out local
  neighborhoods — no cross-client information flow, which is exactly the
  limitation FedGL/SpreadFGL address (Fig. 1 middle vs right).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gnn
from repro.core.fedgl import FGLTrainer, _cross_entropy
from repro.core.types import ClientBatch, FGLConfig
from repro.optim.adam import Adam

PyTree = Any


class LocalFGL(FGLTrainer):
    """Local training only: skip aggregation and imputation."""

    def __init__(self, cfg: FGLConfig, batch: ClientBatch, **kw):
        m = batch.num_clients
        adj = np.ones((1, 1), dtype=np.float32)
        cfg = dataclasses.replace(cfg, num_edge_servers=1, clients_per_server=m)
        super().__init__(cfg, batch, adj, np.zeros(m, np.int32),
                         use_imputation=False, **kw)

    def _aggregate_broadcast(self, params):
        return params  # never aggregate


class FedAvgFusion(FGLTrainer):
    """Classic FedAvg over client GNNs (no imputation generator)."""

    def __init__(self, cfg: FGLConfig, batch: ClientBatch, **kw):
        m = batch.num_clients
        adj = np.ones((1, 1), dtype=np.float32)
        cfg = dataclasses.replace(cfg, num_edge_servers=1, clients_per_server=m)
        super().__init__(cfg, batch, adj, np.zeros(m, np.int32),
                         use_imputation=False, **kw)


class FedSagePlus(FGLTrainer):
    """FedAvg + local linear neighbor generation (no global information flow)."""

    def __init__(self, cfg: FGLConfig, batch: ClientBatch, *, gen_steps: int = 20, **kw):
        m = batch.num_clients
        adj = np.ones((1, 1), dtype=np.float32)
        cfg = dataclasses.replace(cfg, num_edge_servers=1, clients_per_server=m)
        super().__init__(cfg, batch, adj, np.zeros(m, np.int32),
                         use_imputation=True, **kw)
        self.gen_steps = gen_steps
        self._gen_fn = jax.jit(self._run_local_generation)

    # Replace the global imputation round with purely local generation.
    def _imputation_round(self, state_tuple):
        (params, batch, ae_params, ae_opt, as_params, as_opt, key) = state_tuple
        key, kg = jax.random.split(key)
        batch = self._gen_fn(kg, batch)
        return batch, ae_params, ae_opt, as_params, as_opt, key

    def _run_local_generation(self, key, batch: ClientBatch) -> ClientBatch:
        """Per client: train x -> mean(neighbor x) linear predictor, then append
        one generated neighbor for each of the aug_max highest-degree nodes."""
        d = batch.x.shape[-1]
        n_pad = batch.n_pad
        n_local = batch.n_local_max
        aug = batch.aug_max
        opt = Adam(lr=1e-2)

        def per_client(k, x, adjm, node_mask):
            a = adjm[:n_local, :n_local] * (node_mask[:n_local, None] *
                                            node_mask[None, :n_local])
            deg = jnp.sum(a, axis=-1)
            target = (a @ x[:n_local]) / jnp.maximum(deg[:, None], 1.0)
            w = jnp.zeros((d, d), jnp.float32)
            b = jnp.zeros((d,), jnp.float32)

            def loss_fn(p):
                pred = x[:n_local] @ p["w"] + p["b"]
                mask = (deg > 0).astype(x.dtype)
                return jnp.sum(jnp.square(pred - target) * mask[:, None]) / jnp.maximum(
                    jnp.sum(mask), 1.0)

            p = {"w": w, "b": b}
            st = opt.init(p)

            def step(carry, _):
                p, st = carry
                g = jax.grad(loss_fn)(p)
                p, st = opt.update(g, st, p)
                return (p, st), ()
            (p, _), _ = jax.lax.scan(step, (p, st), None, length=self.gen_steps)

            # Highest-degree real nodes get one synthetic neighbor each.
            score = jnp.where(node_mask[:n_local] > 0, deg, -jnp.inf)
            _, src = jax.lax.top_k(score, aug)
            feats = x[src] @ p["w"] + p["b"]
            ok = jnp.isfinite(score[src]).astype(x.dtype)
            aug_rows = n_local + jnp.arange(aug)
            x = x.at[aug_rows].set(feats * ok[:, None])
            adjm = adjm.at[n_local:, :].set(0.0)
            adjm = adjm.at[:, n_local:].set(0.0)
            adjm = adjm.at[src, aug_rows].set(ok)
            adjm = adjm.at[aug_rows, src].set(ok)
            node_mask = node_mask.at[aug_rows].set(ok)
            return x, adjm, node_mask

        keys = jax.random.split(key, batch.num_clients)
        x, adjm, node_mask = jax.vmap(per_client)(keys, batch.x, batch.adj,
                                                  batch.node_mask)
        return batch.replace(x=x, adj=adjm, node_mask=node_mask)


REGISTRY = {
    "local": LocalFGL,
    "fedavg_fusion": FedAvgFusion,
    "fedsage_plus": FedSagePlus,
}
