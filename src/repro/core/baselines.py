"""Comparison algorithms of Sec. IV-A as pure strategy compositions.

No subclassing, no overridden engine internals — each baseline is just a
different (Topology, Aggregator, ImputationStrategy) triple handed to the
shared :class:`~repro.core.fedgl.FGLTrainer`:

- LocalFGL: each client trains its classifier alone — identity aggregation,
  no graph fixing.
- FedAvg-fusion: FedAvg aggregation of client GNNs, no link imputation.
- FedSagePlus: FedAvg + a *local* linear neighbor generator per client
  (Zhang et al., NeurIPS'21 style) — no cross-client information flow, which
  is exactly the limitation FedGL/SpreadFGL address (Fig. 1 middle vs right).

All three are registered in :mod:`repro.core.registry` under the names the
``fgl_train`` launcher uses.
"""
from __future__ import annotations

from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.registry import register
from repro.core.types import ClientBatch, FGLConfig


@register("local")
def LocalFGL(cfg: FGLConfig, batch: ClientBatch, **kw) -> FGLTrainer:
    """Local training only: never aggregate, never impute."""
    return FGLTrainer(cfg, batch, topology=S.StarTopology(),
                      aggregator=S.IdentityAggregator(),
                      imputation=S.NoImputation(), **kw)


@register("fedavg_fusion")
def FedAvgFusion(cfg: FGLConfig, batch: ClientBatch, **kw) -> FGLTrainer:
    """Classic FedAvg over client GNNs (no imputation generator)."""
    return FGLTrainer(cfg, batch, topology=S.StarTopology(),
                      aggregator=S.FedAvgAggregator(),
                      imputation=S.NoImputation(), **kw)


@register("fedsage_plus")
def FedSagePlus(cfg: FGLConfig, batch: ClientBatch, *, gen_steps: int = 20,
                **kw) -> FGLTrainer:
    """FedAvg + local linear neighbor generation (no global information flow)."""
    return FGLTrainer(cfg, batch, topology=S.StarTopology(),
                      aggregator=S.FedAvgAggregator(),
                      imputation=S.LocalGenImputation(gen_steps=gen_steps), **kw)


REGISTRY = {
    "local": LocalFGL,
    "fedavg_fusion": FedAvgFusion,
    "fedsage_plus": FedSagePlus,
}
