"""Pluggable strategy components of the FGL engine.

Algorithm 1 of SpreadFGL is one outer loop; everything the related work
varies lives on three axes, each a small protocol with concrete
implementations here:

- :class:`Topology` — how clients map onto edge servers and how servers are
  wired to each other (star = FedGL's single aggregation point, ring =
  SpreadFGL's testbed, custom adjacency = anything else). AdaFGL-style
  variants swap this axis.
- :class:`Aggregator` — how client classifiers are combined each round
  (FedAvg, Eq. 16 neighbor aggregation, gossip-SGD over the edge mesh,
  FedBuff-style buffered async aggregation, identity for purely local
  training). FedGTA-style variants swap this axis. Aggregators that
  schedule cross-server exchanges (gossip every K rounds) advertise a
  ``period``; the engine passes ``round`` canonicalized to the
  exchange/skip phase so jit sees exactly 2 static variants. Buffered
  aggregators (:class:`AsyncAggregator`) instead expose ``phase``/
  ``round_weights`` hooks — the flush schedule and the staleness weights
  are pure functions of ``(cfg.seed, round)``, so jit still sees exactly
  2 static variants (flush / skip) and save/resume mid-buffer is exact.
- :class:`ImputationStrategy` — what happens on the every-K graph-fixing
  round (the SpreadFGL generator round, FedSage+'s local neighbor
  generation, or nothing).

:class:`~repro.core.fedgl.FGLTrainer` is composed from one of each; the
named compositions live in :mod:`repro.core.registry`. Strategies are
frozen dataclasses (hashable, usable as jit-static closures) and hold no
jax state — per-round state threads through ``FGLState``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import imputation, patcher
from repro.core.partition import group_clients_by_server, ring_adjacency
from repro.core.types import ClientBatch
from repro.optim.adam import Adam

PyTree = Any


# ---------------------------------------------------------------------------
# Topology: client -> edge-server grouping + server-server adjacency.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologyLayout:
    """Resolved edge layout for a concrete client count."""

    adjacency: np.ndarray        # [N, N] server-server weights (a_rj of Eq. 16)
    server_of_client: np.ndarray  # [M] owning server of each client
    num_servers: int
    clients_per_server: int


@runtime_checkable
class Topology(Protocol):
    """Client→edge-server layout + server-server adjacency a_rj (Eq. 16,
    Sec. III-E); resolved once per trainer for a concrete client count."""

    def build(self, num_clients: int) -> TopologyLayout: ...


@dataclasses.dataclass(frozen=True)
class StarTopology:
    """One edge server covering every client (FedGL, Sec. III-B)."""

    def build(self, num_clients: int) -> TopologyLayout:
        return TopologyLayout(np.ones((1, 1), dtype=np.float32),
                              np.zeros(num_clients, dtype=np.int32),
                              1, num_clients)


@dataclasses.dataclass(frozen=True)
class RingTopology:
    """N edge servers on a ring (SpreadFGL's testbed, Sec. III-E).

    Ring structure has ONE source: the adjacency comes verbatim from
    :func:`repro.core.partition.ring_adjacency`; the collective_permute
    schedule in :func:`repro.core.gossip.block_ring_gossip` realizes the
    same matrix implicitly (consistency pinned in
    ``tests/test_gossip.py::TestRingSingleSource``).
    """

    num_servers: int = 3

    def build(self, num_clients: int) -> TopologyLayout:
        n = self.num_servers
        if num_clients % n:
            raise ValueError(f"M={num_clients} must divide across N={n} servers")
        return TopologyLayout(ring_adjacency(n),
                              group_clients_by_server(num_clients, n),
                              n, num_clients // n)


@dataclasses.dataclass(frozen=True, eq=False)
class CustomTopology:
    """Arbitrary server-server adjacency a_rj (Eq. 16 supports any weights;
    AdaFGL-style variants supply theirs here); clients grouped contiguously."""

    adjacency: np.ndarray

    def build(self, num_clients: int) -> TopologyLayout:
        adj = np.asarray(self.adjacency, dtype=np.float32)
        if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
            raise ValueError(f"adjacency must be square, got {adj.shape}")
        n = adj.shape[0]
        if num_clients % n:
            raise ValueError(f"M={num_clients} must divide across N={n} servers")
        return TopologyLayout(adj, group_clients_by_server(num_clients, n),
                              n, num_clients // n)


# ---------------------------------------------------------------------------
# Aggregator: combine client classifiers once per global round.
# ---------------------------------------------------------------------------

def participation_mask(key: jax.Array, num_clients: int, rho: float) -> jnp.ndarray:
    """Sample one round's participating-client mask: [M] float32 0/1.

    Exactly ``ceil(rho * M)`` clients participate, sampled without
    replacement (the classic FedAvg "select a fraction C of clients"
    scheme) — so at least one client always participates and the mask shape
    is static regardless of rho: jit compiles exactly one masked variant,
    never a gather/resize per round.
    """
    if not 0.0 < rho <= 1.0:
        raise ValueError(f"participation must be in (0, 1], got {rho}")
    k = min(num_clients, max(1, int(np.ceil(rho * num_clients - 1e-9))))
    perm = jax.random.permutation(key, num_clients)
    return jnp.zeros((num_clients,), jnp.float32).at[perm[:k]].set(1.0)


def _masked_server_mean(leaf: jnp.ndarray, mask_g: jnp.ndarray,
                        num_servers: int, m_per: int) -> jnp.ndarray:
    """Participation-weighted per-server mean over a grouped leaf.

    ``mask_g`` is the [N, m_per] participation mask. A server whose covered
    clients ALL sit out this round falls back to the plain unweighted mean —
    the edge server re-broadcasts the weights it already holds rather than
    dividing by zero.
    """
    grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
    shaped = mask_g.reshape((num_servers, m_per) + (1,) * (leaf.ndim - 1))
    num = jnp.sum(grouped * shaped, axis=1)
    den = jnp.sum(mask_g, axis=1).reshape((num_servers,) + (1,) * (leaf.ndim - 1))
    plain = jnp.sum(grouped, axis=1) / m_per
    return jnp.where(den > 0, num / jnp.maximum(den, 1.0), plain)


@runtime_checkable
class Aggregator(Protocol):
    """Combine stacked [M] client classifiers once per global round.

    ``round`` is the global round index; the engine canonicalizes it before
    the jitted call (``FGLTrainer._agg_phase``: ``period - 1`` on exchange
    rounds, ``0`` otherwise) — a static Python int, so round-scheduled
    aggregators compile exactly two variants, not one per round. Aggregators
    without a schedule (``period`` 1) ignore it.

    ``mask`` is the optional [M] participation mask of the round
    (:func:`participation_mask`); every mean becomes mask-weighted so
    non-participating clients contribute nothing. ``mask=None`` means full
    participation and MUST take the exact unmasked code path — the engine
    passes None whenever ``cfg.participation == 1`` so fixed-seed goldens
    stay bit-identical.
    """

    def aggregate(self, params: PyTree, *, adj: jnp.ndarray,
                  num_servers: int, m_per: int, round: int = 0,
                  mask: Optional[jnp.ndarray] = None) -> PyTree: ...


@dataclasses.dataclass(frozen=True)
class IdentityAggregator:
    """No aggregation: clients keep their own weights (LocalFGL, Sec. IV-A).

    ``mask`` is accepted and ignored: with no cross-client mixing there is
    nothing for partial participation to gate — a non-participating client
    keeping its own weights is exactly what identity already does.
    """

    def aggregate(self, params, *, adj, num_servers, m_per, round=0, mask=None):
        return params


@dataclasses.dataclass(frozen=True)
class FedAvgAggregator:
    """Per-server FedAvg (McMahan et al.): mean over covered clients,
    broadcast back — classic FGL's single aggregation point when N = 1
    (FedGL, Sec. III-B). With a participation ``mask`` the mean runs over
    the round's participating clients only (all-out servers re-broadcast
    their plain mean, see :func:`_masked_server_mean`)."""

    def aggregate(self, params, *, adj, num_servers, m_per, round=0, mask=None):
        if mask is None:
            def agg(leaf):
                grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
                w = jnp.sum(grouped, axis=1) / m_per
                return jnp.repeat(w, m_per, axis=0)
        else:
            mask_g = mask.reshape(num_servers, m_per)

            def agg(leaf):
                w = _masked_server_mean(leaf, mask_g, num_servers, m_per)
                return jnp.repeat(w, m_per, axis=0)
        return jax.tree.map(agg, params)


@dataclasses.dataclass(frozen=True)
class NeighborAggregator:
    """Eq. 16 (Sec. III-E): each server averages itself and its topology
    neighbors *densely, every round*:

    W_j = sum_r a_rj * sum_i W_(r,i) / sum_r a_rj M_r — the SpreadFGL rule
    that removes the single aggregation point. :class:`GossipAggregator`
    computes the identical update on exchange rounds but amortizes the
    cross-server traffic over K rounds; with ``every_k=1`` on the same
    adjacency the two are numerically interchangeable
    (``tests/test_gossip.py`` pins the allclose).

    With a participation ``mask``, Eq. 16's client count M_r becomes the
    round's participating count m̃_r (mask-weighted sums in both numerator
    and denominator); a neighborhood that entirely sat out falls back to the
    plain Eq. 16 mix.
    """

    def aggregate(self, params, *, adj, num_servers, m_per, round=0, mask=None):
        if mask is None:
            def agg(leaf):
                grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
                client_sum = jnp.sum(grouped, axis=1)              # [N, ...]
                num = jnp.einsum("rj,r...->j...", adj, client_sum)
                den = jnp.sum(adj, axis=0) * m_per                 # [N]
                w = num / den.reshape((num_servers,) + (1,) * (leaf.ndim - 1))
                return jnp.repeat(w, m_per, axis=0)
        else:
            mask_g = mask.reshape(num_servers, m_per)
            counts = jnp.sum(mask_g, axis=1)                       # m̃_r [N]

            def agg(leaf):
                grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
                shaped = mask_g.reshape((num_servers, m_per) + (1,) * (leaf.ndim - 1))
                tail = (1,) * (leaf.ndim - 1)
                num = jnp.einsum("rj,r...->j...", adj,
                                 jnp.sum(grouped * shaped, axis=1))
                den = jnp.einsum("r,rj->j", counts, adj).reshape((num_servers,) + tail)
                plain_num = jnp.einsum("rj,r...->j...", adj, jnp.sum(grouped, axis=1))
                plain_den = (jnp.sum(adj, axis=0) * m_per).reshape((num_servers,) + tail)
                w = jnp.where(den > 0, num / jnp.maximum(den, 1.0),
                              plain_num / plain_den)
                return jnp.repeat(w, m_per, axis=0)
        return jax.tree.map(agg, params)


@dataclasses.dataclass(frozen=True, eq=False)
class GossipAggregator:
    """Sec. III-E load balancing as gossip-SGD over the edge mesh.

    Each round every server FedAvg-aggregates its own covered clients
    (edge-client traffic only); cross-server parameter exchange happens
    only every ``every_k`` rounds, with topology neighbors (Eq. 16 weights)
    rather than a dense all-to-all — the decentralized-training reading of
    the paper's Fig. 8/9 convergence claim, a la FedGTA's topology-aware
    averaging. Per-round cross-server bytes drop from every-round dense
    Eq. 16 to 2·|W|/K (``core.gossip.ring_gossip_bytes_per_round``).

    ``topology`` picks the exchange kernel: ``"ring"`` uses
    :func:`repro.core.gossip.block_ring_gossip`'s boundary-slice
    ``collective_permute`` schedule (N ≥ 3; N ≤ 2 falls back to the
    adjacency path, where a 2-ring's double edge would otherwise be
    over-counted), ``"adjacency"`` uses
    :func:`repro.core.gossip.adjacency_gossip` (all_gather + Eq. 16 mix)
    for star/custom wiring. With ``mesh`` set (``make_edge_mesh``) the
    exchange runs under ``shard_map`` over the mesh's [N] axis, so the
    neighbor bytes genuinely cross the (emulated) device boundary.

    Equivalences, both pinned in ``tests/test_gossip.py``:

    - ``GossipAggregator(every_k=1)`` == :class:`NeighborAggregator` on the
      same adjacency (ring or custom), to float32 tolerance.
    - On non-exchange rounds it equals :class:`FedAvgAggregator` applied
      per server.

    The gossip round-phase is ``state.round % every_k`` — a pure function
    of the checkpointed round, so save/resume mid-interval keeps the
    exchange schedule intact.
    """

    topology: str = "ring"        # "ring" | "adjacency"
    every_k: int = 1
    mesh: Any = None              # optional jax Mesh carrying the [N] axis

    def __post_init__(self):
        if self.topology not in ("ring", "adjacency"):
            raise ValueError(f"unknown gossip topology {self.topology!r}; "
                             f"expected 'ring' or 'adjacency'")
        if self.every_k < 1:
            raise ValueError(f"every_k must be >= 1, got {self.every_k}")

    @property
    def period(self) -> int:
        """Exchange schedule length; the engine passes ``round`` mod this."""
        return self.every_k

    def aggregate(self, params, *, adj, num_servers, m_per, round=0, mask=None):
        if mask is None:
            def server_mean(leaf):
                grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
                return jnp.sum(grouped, axis=1) / m_per
        else:
            # Participation gates the edge-client leg only: the per-server
            # mean runs over participating clients (all-out servers keep
            # their plain mean); the cross-server exchange is unchanged —
            # servers always gossip whatever they aggregated this round.
            mask_g = mask.reshape(num_servers, m_per)

            def server_mean(leaf):
                return _masked_server_mean(leaf, mask_g, num_servers, m_per)

        w = jax.tree.map(server_mean, params)                  # [N, ...]
        if num_servers > 1 and (round + 1) % self.every_k == 0:
            w = self._exchange(w, adj, num_servers)
        return jax.tree.map(lambda leaf: jnp.repeat(leaf, m_per, axis=0), w)

    def _exchange(self, w: PyTree, adj, num_servers: int) -> PyTree:
        from repro.core import gossip

        use_ring = self.topology == "ring" and num_servers >= 3
        if self.mesh is not None and self.mesh.size > 1:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            axis = self.mesh.axis_names[0]

            def ex(blk):
                if use_ring:
                    return gossip.block_ring_gossip(blk, axis)
                return gossip.adjacency_gossip(blk, adj, axis)

            return shard_map(ex, mesh=self.mesh, in_specs=(P(axis),),
                             out_specs=P(axis), check_rep=False)(w)
        if use_ring:
            return gossip.block_ring_gossip(w)
        return gossip.adjacency_gossip(w, adj)


# ---------------------------------------------------------------------------
# Async straggler-tolerant aggregation (FedBuff-style).
# ---------------------------------------------------------------------------

ASYNC_DELAY_DISTS = ("zero", "uniform", "geometric")

# Salt for the async delay/dropout key stream. Distinct from the
# participation salt (0x9A57 in FGLTrainer) and never folded into the
# training key threaded through FGLState: enabling async aggregation does
# not perturb any other random stream, and the round-t draws are a pure
# function of (seed, t) — the property that makes mid-buffer resume exact.
_ASYNC_SALT = 0xA57C


def async_delay_stream(seed: int, round: int, num_clients: int, *,
                       delay_dist: str = "zero", max_delay: int = 4,
                       dropout_rate: float = 0.0):
    """Round-``round`` arrival delays and dropout flags, per client.

    Returns ``(delays int32 [M], drops bool [M])`` numpy arrays: ``delays[i]``
    is how many rounds client i's update sent this round stays in flight
    (0 = arrives the same round), ``drops[i]`` marks a mid-round dropout —
    the update is lost at send time and the client retries next round.

    The draws come from ``fold_in(fold_in(key(seed), salt), round)`` — the
    same keyed-stream idiom as :func:`participation_mask` but under a
    different salt, so the two schedules are independent of each other AND
    of the training key. Same (seed, round) always reproduces the same
    delays; a checkpoint restored at round t replays rounds 0..t-1 of the
    stream to rebuild the buffer exactly.

    Distributions: ``"zero"`` — no delay (the synchronous limit);
    ``"uniform"`` — uniform on {0..max_delay}; ``"geometric"`` — p=1/2
    geometric on {0, 1, 2, ...} (mean 1), capped at ``max_delay``.
    """
    if delay_dist not in ASYNC_DELAY_DISTS:
        raise ValueError(f"unknown delay_dist {delay_dist!r}; "
                         f"expected one of {ASYNC_DELAY_DISTS}")
    if max_delay < 0:
        raise ValueError(f"max_delay must be >= 0, got {max_delay}")
    if not 0.0 <= dropout_rate < 1.0:
        raise ValueError(f"dropout_rate must be in [0, 1), got {dropout_rate}")
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), _ASYNC_SALT), round)
    kd, kx = jax.random.split(key)
    if delay_dist == "zero":
        delays = np.zeros(num_clients, np.int32)
    elif delay_dist == "uniform":
        delays = np.asarray(jax.random.randint(kd, (num_clients,), 0,
                                               max_delay + 1), np.int32)
    else:  # geometric, p = 1/2 via inverse transform
        u = np.asarray(jax.random.uniform(kd, (num_clients,)), np.float64)
        delays = np.minimum(np.floor(np.log1p(-u) / np.log(0.5)),
                            max_delay).astype(np.int32)
    drops = np.asarray(jax.random.uniform(kx, (num_clients,)) < dropout_rate)
    return delays, drops


# spec -> incremental replay state; see _async_schedule. Purely a cache:
# entries are reproducible from scratch, so sharing across trainer
# instances (same spec => same schedule) is sound.
_ASYNC_SCHEDULES: dict = {}


def _async_schedule(spec: tuple, round: int):
    """``(flush, weights)`` of round ``round`` for one async spec.

    ``spec = (seed, num_clients, buffer_size, delay_dist, max_delay,
    dropout_rate)``. Replays the deterministic client state machine from
    round 0 (cached incrementally, so sequential training pays O(M) per
    round and a mid-run resume pays one O(t·M) host-side replay):

    - a client with no update in flight sends one every round; the round's
      :func:`async_delay_stream` draw gives its arrival delay, or drops it
      (mid-round dropout — the client just retries next round);
    - an update arriving at round t joins the server buffer with report
      round t (one buffer slot per client — a fresher arrival replaces a
      staler unflushed one, which keeps the buffer a static [M] mask);
    - when >= buffer_size updates sit in the buffer at the end of a round,
      the server flushes: ``weights[i] = 1/sqrt(1 + t - report[i])`` for
      buffered clients (the FedBuff staleness discount), 0 elsewhere, and
      the buffer empties.

    On non-flush rounds weights is None (aggregation is identity).
    """
    seed, m, buffer_size, delay_dist, max_delay, dropout_rate = spec
    cache = _ASYNC_SCHEDULES.setdefault(spec, {
        "next": 0,
        "arrival": np.full(m, -1, np.int64),   # in-flight arrival round
        "report": np.full(m, -1, np.int64),    # buffered report round
        "out": [],
    })
    arrival, report = cache["arrival"], cache["report"]
    while cache["next"] <= round:
        t = cache["next"]
        delays, drops = async_delay_stream(
            seed, t, m, delay_dist=delay_dist, max_delay=max_delay,
            dropout_rate=dropout_rate)
        free = arrival < 0
        send = free & ~drops
        arrival[send] = t + delays[send]
        arrived = arrival == t
        report[arrived] = t
        arrival[arrived] = -1
        buffered = report >= 0
        if int(buffered.sum()) >= buffer_size:
            tau = (t - report).astype(np.float32)
            weights = np.where(buffered,
                               1.0 / np.sqrt(np.float32(1.0) + tau),
                               np.float32(0.0)).astype(np.float32)
            report[:] = -1
            cache["out"].append((True, weights))
        else:
            cache["out"].append((False, None))
        cache["next"] = t + 1
    return cache["out"][round]


@dataclasses.dataclass(frozen=True)
class AsyncAggregator:
    """Buffered straggler-tolerant aggregation (FedBuff, Nguyen et al. '22).

    Every synchronous round in the engine is a barrier: one straggling
    client stalls the whole mesh — exactly the single-point overload the
    paper's edge layer argues against (Sec. I, Sec. III-E). This
    aggregator removes the barrier in simulation: client updates *report*
    to the server with per-round arrival delays and mid-round dropouts
    (:func:`async_delay_stream`), the server buffers reports, and
    aggregation triggers only when the buffer holds at least
    ``buffer_size`` updates — never "when all M clients arrive". On a
    flush each edge server takes the staleness-discounted weighted mean of
    its covered *buffered* clients,

        W_j = sum_i w_i W_(j,i) / sum_i w_i,   w_i = 1 / sqrt(1 + tau_i),

    with tau_i = flush round - report round (the FedBuff discount), and
    broadcasts it to all its clients; a server with no buffered reports
    keeps its clients' weights untouched. Non-flush rounds are identity —
    clients simply keep training locally.

    Determinism contract (the same one ``participation_mask`` and the
    gossip phase honor): the delay/dropout draws come from a key stream =
    f(cfg.seed, absolute round) under a dedicated salt, the buffer is a
    static [M] occupancy (freshest report per client wins — no Python-list
    buffer, no gather/resize), and the flush weights reach the jitted
    aggregation as a traced [M] vector with flush/skip as the only static
    split. The whole delay/buffer/staleness schedule is therefore a pure
    function of the checkpointed round: save/resume mid-buffer replays
    rounds 0..t-1 on the host and continues bit-exactly
    (``tests/test_async_agg.py``).

    Correctness anchor: with ``buffer_size = M``, ``delay_dist="zero"``,
    and ``dropout_rate = 0`` every client reports every round, the buffer
    fills exactly at M, every tau is 0, and every weight is exactly 1.0 —
    the flush reduces to the per-server mean over covered clients and the
    histories reproduce :class:`FedAvgAggregator` bit-identically (pinned
    in ``tests/test_async_agg.py``, the same way K=1 gossip pins dense
    neighbor aggregation).
    """

    buffer_size: int = 1
    delay_dist: str = "zero"      # "zero" | "uniform" | "geometric"
    dropout_rate: float = 0.0     # P(update lost at send), per client-round
    max_delay: int = 4            # delay cap in rounds
    seed: int = 0

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.delay_dist not in ASYNC_DELAY_DISTS:
            raise ValueError(f"unknown delay_dist {self.delay_dist!r}; "
                             f"expected one of {ASYNC_DELAY_DISTS}")
        if not 0.0 <= self.dropout_rate < 1.0:
            raise ValueError(f"dropout_rate must be in [0, 1), "
                             f"got {self.dropout_rate}")
        if self.max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {self.max_delay}")

    def _spec(self, num_clients: int) -> tuple:
        if self.buffer_size > num_clients:
            raise ValueError(
                f"buffer_size={self.buffer_size} can never fill: the buffer "
                f"holds at most one update per client (M={num_clients})")
        return (self.seed, num_clients, self.buffer_size, self.delay_dist,
                self.max_delay, self.dropout_rate)

    def phase(self, round: int, num_clients: int) -> int:
        """1 on flush rounds, 0 otherwise — the static arg of the jitted
        aggregation call, so jit compiles exactly 2 variants."""
        flush, _ = _async_schedule(self._spec(num_clients), round)
        return int(flush)

    def round_weights(self, round: int, num_clients: int):
        """[M] float32 staleness weights on flush rounds, else None."""
        _, weights = _async_schedule(self._spec(num_clients), round)
        return None if weights is None else jnp.asarray(weights)

    def aggregate(self, params, *, adj, num_servers, m_per, round=0, mask=None):
        """``round`` is the flush phase (1 = flush); ``mask`` carries the
        [M] staleness weights (zero = not buffered). Skip rounds are
        identity. ``adj`` is unused: like :class:`FedAvgAggregator` the
        flush is per-server — cross-server spread still happens through
        the shared imputation round."""
        if not round or mask is None:
            return params
        mask_g = jnp.asarray(mask, jnp.float32).reshape(num_servers, m_per)
        den = jnp.sum(mask_g, axis=1)                       # [N] total weight

        def agg(leaf):
            grouped = leaf.reshape((num_servers, m_per) + leaf.shape[1:])
            tail = (1,) * (leaf.ndim - 1)
            shaped = mask_g.reshape((num_servers, m_per) + tail)
            num = jnp.sum(grouped * shaped, axis=1)
            den_s = den.reshape((num_servers,) + tail)
            w = num / jnp.where(den_s > 0, den_s, 1.0)
            keep = jnp.repeat(den > 0, m_per).reshape(
                (num_servers * m_per,) + tail)
            return jnp.where(keep, jnp.repeat(w, m_per, axis=0), leaf)
        return jax.tree.map(agg, params)


# ---------------------------------------------------------------------------
# ImputationStrategy: the every-K graph-fixing round.
# ---------------------------------------------------------------------------

@runtime_checkable
class ImputationStrategy(Protocol):
    """The every-K graph-fixing round (Algorithm 1 lines 11-24 for
    SpreadFGL; FedSage+'s local generation; or nothing). ``active=False``
    lets the engine skip the round entirely."""

    active: bool

    def impute(self, engine, state): ...


@dataclasses.dataclass(frozen=True)
class NoImputation:
    """Skip graph fixing entirely (LocalFGL / FedAvg-fusion baselines)."""

    active = False

    def impute(self, engine, state):
        return state


@dataclasses.dataclass(frozen=True, eq=False)
class SpreadImputation:
    """SpreadFGL's generator round (Algorithm 1 lines 11-24).

    Fuse client embeddings per server, train the AE/assessor pair
    adversarially, take cross-subgraph top-k similarity links, and fix every
    client graph through the graphic patcher. The [N] server axis is a single
    vmap (shardable across an edge mesh); per-server results are stitched
    back to the global flat index space by
    :func:`patcher.stitch_server_links`.

    With ``sim_mesh`` set (same pattern as ``GossipAggregator.mesh``) the
    similarity top-k is lifted OUT of the vmapped server round and runs once,
    batched over the [N] axis, through the candidate-sharded ring driver
    (:mod:`repro.core.ring_topk`): each mesh device owns an [n/size] slice of
    every server's candidate axis and slabs rotate via collective_permute.
    The ring result is bit-identical to the in-vmap reference, so the two
    layouts are interchangeable (pinned in ``tests/test_ring_topk.py``).
    """

    sim_mesh: Any = None          # optional jax Mesh to shard candidates over

    active = True

    def server_outputs(self, engine, state):
        """The vmapped [N] generator round, before graph fixing.

        Returns ``((ae_params, ae_opt, as_params, as_opt, scores, idx,
        x_bar), key)`` with per-server leading [N] axes and the advanced
        round key — the raw link proposals the parity regressions inspect.
        """
        batch = state.batch
        emb = engine._embeddings(state.params, batch)       # [M, n_pad, c]
        n_pad = batch.x.shape[1]
        n, mp = engine.n_servers, engine.m_per
        emb_g = emb.reshape((n, mp) + emb.shape[1:])        # [N, M_per, n_pad, c]
        mask_g = batch.node_mask.reshape(n, mp, n_pad)
        keys = jax.random.split(state.key, n + 1)
        key, server_keys = keys[0], keys[1:]
        client_ids = imputation.client_of_flat(mp, n_pad)
        if self.sim_mesh is None:
            outs = jax.vmap(
                engine._server_round, in_axes=(0, 0, 0, 0, 0, 0, 0, None)
            )(server_keys, state.ae_params, state.ae_opt, state.as_params,
              state.as_opt, emb_g, mask_g, client_ids)
            return outs, key
        # Sharded path: vmap ONLY the generator half; the similarity runs
        # once over the stacked [N, n_flat, c] fused embeddings so shard_map
        # is the outermost transform (vmap-inside-shard_map composes; the
        # reverse does not). Numerically identical: the generator consumes
        # all the round's randomness, similarity is deterministic in h_flat.
        (ae, aeo, asr, aso, x_bar, h_all, fmask_all) = jax.vmap(
            engine._server_round_gen, in_axes=(0, 0, 0, 0, 0, 0, 0)
        )(server_keys, state.ae_params, state.ae_opt, state.as_params,
          state.as_opt, emb_g, mask_g)
        tmask_all = fmask_all * imputation.local_slot_mask(
            mp, n_pad, engine.n_local)[None, :]
        cid_all = jnp.broadcast_to(client_ids, fmask_all.shape)
        scores, idx = imputation.similarity_topk(
            h_all, fmask_all, cid_all, engine.cfg.top_k_links,
            kernel_impl=engine.kernel_impl, target_mask=tmask_all,
            mesh=self.sim_mesh)
        return (ae, aeo, asr, aso, scores, idx, x_bar), key

    def impute(self, engine, state):
        (ae_params, ae_opt, as_params, as_opt, scores, idx,
         x_bar), key = self.server_outputs(engine, state)
        scores, idx, x_bar = patcher.stitch_server_links(scores, idx, x_bar)
        batch = patcher.fix_graphs(state.batch, scores, idx, x_bar)
        return dataclasses.replace(state, batch=batch, ae_params=ae_params,
                                   ae_opt=ae_opt, as_params=as_params,
                                   as_opt=as_opt, key=key)

    def impute_reference(self, engine, state):
        """Sequential per-server loop (tests/benchmarks only).

        Preserves the pre-refactor structure — a Python loop running one
        server at a time — but uses the same per-server key derivation as
        :meth:`impute` (one ``split(key, N+1)`` up front), so the two are
        numerically equivalent and the equivalence test isolates exactly the
        loop→vmap change. Also the baseline the load-balance benchmark times
        against.
        """
        batch = state.batch
        emb = engine._embeddings(state.params, batch)       # [M, n_pad, c]
        n_pad = batch.x.shape[1]
        keys = jax.random.split(state.key, engine.n_servers + 1)
        key, server_keys = keys[0], keys[1:]
        client_ids = imputation.client_of_flat(engine.m_per, n_pad)
        outs = []
        for j in range(engine.n_servers):
            sl = slice(j * engine.m_per, (j + 1) * engine.m_per)
            take_j = lambda t: jax.tree.map(lambda x: x[j], t)
            outs.append(engine._server_round(
                server_keys[j], take_j(state.ae_params), take_j(state.ae_opt),
                take_j(state.as_params), take_j(state.as_opt), emb[sl],
                batch.node_mask[sl], client_ids))
        stack = lambda i: jax.tree.map(lambda *x: jnp.stack(x), *[o[i] for o in outs])
        ae_params, ae_opt, as_params, as_opt = (stack(i) for i in range(4))
        scores, idx, x_bar = patcher.stitch_server_links(
            stack(4), stack(5), stack(6))
        batch = patcher.fix_graphs(batch, scores, idx, x_bar)
        return dataclasses.replace(state, batch=batch, ae_params=ae_params,
                                   ae_opt=ae_opt, as_params=as_params,
                                   as_opt=as_opt, key=key)


@dataclasses.dataclass(frozen=True)
class LocalGenImputation:
    """FedSage+-style purely local neighbor generation (Zhang et al. '21).

    Per client: train a linear x -> mean(neighbor x) predictor on the
    client's own neighborhoods, then append one synthetic neighbor for each
    of the ``aug_max`` highest-degree nodes. No cross-client information
    flows — exactly the limitation FedGL/SpreadFGL address (Fig. 1).
    """

    gen_steps: int = 20

    active = True

    def impute(self, engine, state):
        key, kg = jax.random.split(state.key)
        batch = _local_generation(kg, state.batch, self.gen_steps)
        return dataclasses.replace(state, batch=batch, key=key)


def _local_generation(key, batch: ClientBatch, gen_steps: int) -> ClientBatch:
    d = batch.x.shape[-1]
    n_local = batch.n_local_max
    aug = batch.aug_max
    opt = Adam(lr=1e-2)

    def per_client(k, x, adjm, node_mask):
        a = adjm[:n_local, :n_local] * (node_mask[:n_local, None] *
                                        node_mask[None, :n_local])
        deg = jnp.sum(a, axis=-1)
        target = (a @ x[:n_local]) / jnp.maximum(deg[:, None], 1.0)

        def loss_fn(p):
            pred = x[:n_local] @ p["w"] + p["b"]
            mask = (deg > 0).astype(x.dtype)
            return jnp.sum(jnp.square(pred - target) * mask[:, None]) / jnp.maximum(
                jnp.sum(mask), 1.0)

        p = {"w": jnp.zeros((d, d), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
        st = opt.init(p)

        def step(carry, _):
            p, st = carry
            g = jax.grad(loss_fn)(p)
            p, st = opt.update(g, st, p)
            return (p, st), ()
        (p, _), _ = jax.lax.scan(step, (p, st), None, length=gen_steps)

        # Highest-degree real nodes get one synthetic neighbor each.
        score = jnp.where(node_mask[:n_local] > 0, deg, -jnp.inf)
        _, src = jax.lax.top_k(score, aug)
        feats = x[src] @ p["w"] + p["b"]
        ok = jnp.isfinite(score[src]).astype(x.dtype)
        aug_rows = n_local + jnp.arange(aug)
        x = x.at[aug_rows].set(feats * ok[:, None])
        adjm = adjm.at[n_local:, :].set(0.0)
        adjm = adjm.at[:, n_local:].set(0.0)
        adjm = adjm.at[src, aug_rows].set(ok)
        adjm = adjm.at[aug_rows, src].set(ok)
        node_mask = node_mask.at[aug_rows].set(ok)
        return x, adjm, node_mask

    keys = jax.random.split(key, batch.num_clients)
    x, adjm, node_mask = jax.vmap(per_client)(keys, batch.x, batch.adj,
                                              batch.node_mask)
    return batch.replace(x=x, adj=adjm, node_mask=node_mask)
