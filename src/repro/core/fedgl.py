"""FedGL / SpreadFGL training engine (Algorithm 1).

One engine covers both frameworks: ``num_edge_servers == 1`` with a trivial
adjacency is FedGL (Sec. III-B); ``num_edge_servers > 1`` with a ring adjacency
and the Eq. 15 trace regularizer + Eq. 16 neighbor aggregation is SpreadFGL
(Sec. III-E).

Layout: client classifiers are stacked on a leading [M] axis; clients are
grouped contiguously per server so a ``[N, M_per]`` reshape recovers the edge
topology. All per-edge-server state (autoencoder, assessor, and their
optimizer states) is likewise stacked on a leading ``[N]`` axis — there are no
Python lists of per-server pytrees — and the whole imputation round is a
single ``jax.vmap`` over that axis, so N servers run data-parallel instead of
sequentially. When an edge mesh is supplied (``launch/edge_mesh.py``) the
``[N]`` axis is placed on a JAX device mesh and the vmapped round shards
across devices. Everything jits; the outer edge-client communication loop is
a Python loop (it mutates graph structure on imputation rounds).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assessor as assessor_lib
from repro.core import gnn, imputation, patcher
from repro.core.types import ClientBatch, FGLConfig
from repro.optim.adam import Adam

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FGLState:
    """Registered pytree so the whole state checkpoints/shards as one tree."""

    params: PyTree        # [M, ...] stacked client classifiers
    opt_state: Any
    ae_params: PyTree     # [N, ...] stacked per-server autoencoders
    ae_opt: Any           # [N, ...] stacked optimizer state
    as_params: PyTree     # [N, ...] stacked per-server assessors
    as_opt: Any
    batch: ClientBatch
    key: jax.Array
    round: int = 0


def _cross_entropy(logits: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): masked CE; logits [n, c], y [n] with -1 on unlabeled."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe_y = jnp.maximum(y, 0)
    picked = jnp.take_along_axis(logp, safe_y[:, None], axis=-1)[:, 0]
    mask = mask * (y >= 0)
    return -jnp.sum(picked * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _trace_reg(params: PyTree) -> jnp.ndarray:
    """Eq. (15): Tr(W_L W_Lᵀ) = ||W_L||_F² on the last GNN layer's weights."""
    last = params["layers"][-1]
    return sum(jnp.sum(jnp.square(w)) for k, w in last.items() if k != "b")


class FGLTrainer:
    """Drives Algorithm 1 for a fixed client batch."""

    def __init__(self, cfg: FGLConfig, batch: ClientBatch, server_adjacency: np.ndarray,
                 server_of_client: np.ndarray, *, aggregate_impl: str = "reference",
                 use_negative_sampling: bool = True, use_assessor: bool = True,
                 use_imputation: bool = True, edge_mesh=None):
        self.cfg = cfg
        self.num_classes = batch.num_classes
        self.n_servers = int(server_adjacency.shape[0])
        self.m = batch.num_clients
        if self.m % self.n_servers:
            raise ValueError("clients must split evenly across servers")
        self.m_per = self.m // self.n_servers
        expected = np.repeat(np.arange(self.n_servers), self.m_per)
        if not np.array_equal(np.asarray(server_of_client), expected):
            raise ValueError("clients must be grouped contiguously per server")
        self.adj_servers = jnp.asarray(server_adjacency, jnp.float32)
        self.feature_dim = batch.x.shape[-1]
        self.aggregate_impl = aggregate_impl
        self.use_ns = use_negative_sampling
        self.use_assessor = use_assessor
        self.use_imputation = use_imputation
        self.opt = Adam(lr=cfg.lr_classifier)
        self.gen_opt = Adam(lr=cfg.lr_generator)
        self.is_spread = self.n_servers > 1
        self.edge_mesh = edge_mesh
        if edge_mesh is not None and self.n_servers % edge_mesh.size:
            raise ValueError(f"N={self.n_servers} servers must divide across the "
                             f"{edge_mesh.size}-device edge mesh")
        self._local_fn = jax.jit(self._local_rounds)
        self._agg_fn = jax.jit(self._aggregate_broadcast)
        self._impute_fn = jax.jit(self._imputation_round)
        self._eval_fn = jax.jit(self._evaluate)

    # -- initialization ------------------------------------------------------

    def init(self, key: jax.Array, batch: ClientBatch) -> FGLState:
        cfg = self.cfg
        dims = [self.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1) + [self.num_classes]
        k_cls, k_ae, k_as, k_run = jax.random.split(key, 4)
        # Algorithm 1 line 3: all clients start from the server weights W_j.
        base = gnn.init_classifier(k_cls, cfg.gnn_kind, dims)
        params = jax.tree.map(lambda p: jnp.broadcast_to(p, (self.m,) + p.shape).copy(), base)
        ae_params = imputation.init_stacked_autoencoder(
            k_ae, self.n_servers, self.num_classes, self.feature_dim, cfg.ae_hidden)
        as_params = assessor_lib.init_stacked_assessor(
            k_as, self.n_servers, self.num_classes, cfg.assessor_hidden)
        ae_opt = jax.vmap(self.gen_opt.init)(ae_params)
        as_opt = jax.vmap(self.gen_opt.init)(as_params)
        ae_params, ae_opt, as_params, as_opt = self._shard_edge(
            (ae_params, ae_opt, as_params, as_opt))
        batch = jax.tree.map(jnp.asarray, batch)
        return FGLState(params=params, opt_state=self.opt.init(params),
                        ae_params=ae_params, ae_opt=ae_opt,
                        as_params=as_params, as_opt=as_opt,
                        batch=batch, key=k_run)

    def _shard_edge(self, tree: PyTree) -> PyTree:
        """Place the leading [N] server axis of stacked state on the edge mesh."""
        if self.edge_mesh is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec
        spec = NamedSharding(self.edge_mesh,
                             PartitionSpec(self.edge_mesh.axis_names[0]))
        return jax.tree.map(lambda x: jax.device_put(x, spec), tree)

    # -- local training (Algorithm 1 lines 8-9) ------------------------------

    def _client_loss(self, params_m: PyTree, batch: ClientBatch) -> jnp.ndarray:
        def one(params, x, adj, y, node_mask, train_mask):
            logits = gnn.apply_classifier(params, self.cfg.gnn_kind, x, adj, node_mask,
                                          impl=self.aggregate_impl)
            loss = _cross_entropy(logits, y, train_mask)
            if self.is_spread and self.cfg.trace_reg > 0:
                loss = loss + self.cfg.trace_reg * _trace_reg(params)
            return loss
        losses = jax.vmap(one)(params_m, batch.x, batch.adj, batch.y,
                               batch.node_mask, batch.train_mask)
        return jnp.sum(losses)  # sum => per-client grads stay independent

    def _local_rounds(self, params, opt_state, batch: ClientBatch):
        def step(carry, _):
            params, opt_state = carry
            grads = jax.grad(self._client_loss)(params, batch)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), ()
        (params, opt_state), _ = jax.lax.scan(step, (params, opt_state), None,
                                              length=self.cfg.local_rounds)
        return params, opt_state

    # -- aggregation (FedAvg / Eq. 16) ----------------------------------------

    def _aggregate_broadcast(self, params: PyTree) -> PyTree:
        n, mp = self.n_servers, self.m_per

        def agg(leaf):
            grouped = leaf.reshape((n, mp) + leaf.shape[1:])
            client_sum = jnp.sum(grouped, axis=1)             # [N, ...]
            if self.is_spread:
                # Eq. 16: W_j = sum_r a_rj * sum_i W_(r,i) / sum_r a_rj M_r
                weights = self.adj_servers  # a_rj, rows r cols j
                num = jnp.einsum("rj,r...->j...", weights, client_sum)
                den = jnp.sum(weights, axis=0) * mp           # [N]
                w = num / den.reshape((n,) + (1,) * (leaf.ndim - 1))
            else:
                w = client_sum / mp
            return jnp.repeat(w, mp, axis=0)                   # broadcast to clients
        return jax.tree.map(agg, params)

    # -- imputation + graph fixing (Algorithm 1 lines 11-24) ------------------

    def _embeddings(self, params, batch: ClientBatch) -> jnp.ndarray:
        def one(p, x, adj, mask):
            logits = gnn.apply_classifier(p, self.cfg.gnn_kind, x, adj, mask,
                                          impl=self.aggregate_impl)
            return jax.nn.softmax(logits, axis=-1)
        return jax.vmap(one)(params, batch.x, batch.adj, batch.node_mask)

    def _train_generator(self, key, ae, ae_opt, asr, as_opt, h_real, flat_mask):
        """Alternating AE / assessor training (Algorithm 1 lines 16-23).

        The noise matrix S is sampled ONCE per imputation round and held fixed
        across AE/assessor iterations, so that row v of S is bound to node v:
        the masked reconstruction term of Eq. (14) then makes h(f(S))_v track
        h_v and the encoder output X̅_v = f(S)_v is a node-specific imputed
        feature (Sec. III-C: "X̅ = f(S) indicates the potential features").
        Returns (ae, ae_opt, asr, as_opt, s_noise).
        """
        cfg = self.cfg
        theta = cfg.theta(self.num_classes)
        n = h_real.shape[0]
        e = (assessor_lib.negative_mask(h_real, theta) if self.use_ns
             else jnp.ones_like(h_real))
        key, ks = jax.random.split(key)
        s_noise = imputation.sample_noise(ks, n, self.num_classes)

        def ae_step(carry, k):
            ae, ae_opt = carry
            s = s_noise
            if self.use_assessor:
                loss_fn = lambda p: assessor_lib.autoencoder_loss(
                    p, asr_current[0], s, h_real, e, flat_mask)
            else:
                # w/o assessor: plain masked reconstruction of H (Fig. 7 ablation).
                def loss_fn(p):
                    _, h_fake = imputation.reconstruct(p, s)
                    diff = (h_real - h_fake)
                    return jnp.sum(jnp.sum(diff * diff, -1) * flat_mask) / jnp.maximum(
                        jnp.sum(flat_mask), 1.0)
            grads = jax.grad(loss_fn)(ae)
            ae, ae_opt = self.gen_opt.update(grads, ae_opt, ae)
            return (ae, ae_opt), ()

        def as_step(carry, k):
            asr, as_opt = carry
            _, h_fake = imputation.reconstruct(ae_current[0], s_noise)
            if self.use_ns:
                loss_fn = lambda p: assessor_lib.assessor_loss(p, h_real, h_fake, e, flat_mask)
            else:
                loss_fn = lambda p: assessor_lib.assessor_loss_plain(p, h_real, h_fake, flat_mask)
            grads = jax.grad(loss_fn)(asr)
            asr, as_opt = self.gen_opt.update(grads, as_opt, asr)
            return (asr, as_opt), ()

        for _ in range(cfg.ae_outer_iters):
            key, k1, k2 = jax.random.split(key, 3)
            asr_current = (asr, as_opt)
            (ae, ae_opt), _ = jax.lax.scan(ae_step, (ae, ae_opt),
                                           jax.random.split(k1, cfg.ae_iters))
            ae_current = (ae, ae_opt)
            if self.use_assessor:
                (asr, as_opt), _ = jax.lax.scan(as_step, (asr, as_opt),
                                                jax.random.split(k2, cfg.assessor_iters))
        return ae, ae_opt, asr, as_opt, s_noise

    def _server_round(self, key_j, ae, aeo, asr, aso, emb_j, mask_j, client_ids):
        """One edge server's imputation work on its [M_per, n_pad, c] slice."""
        cfg = self.cfg
        h_flat, flat_mask = imputation.fuse_embeddings(emb_j, mask_j)
        ae, aeo, asr, aso, s_noise = self._train_generator(
            key_j, ae, aeo, asr, aso, h_flat, flat_mask)
        scores, idx = imputation.similarity_topk(
            h_flat, flat_mask, client_ids, cfg.top_k_links)
        x_bar = imputation.encode(ae, s_noise)              # X̅ = f(S), same S
        return ae, aeo, asr, aso, scores, idx, x_bar

    def _imputation_round(self, state_tuple):
        """All servers at once: fuse -> top-k -> AE/assessor -> fix graphs.

        The [N] server axis is a single vmap (shardable across an edge mesh);
        per-server results are stitched back to the global flat index space by
        :func:`patcher.stitch_server_links`.
        """
        (params, batch, ae_params, ae_opt, as_params, as_opt, key) = state_tuple
        emb = self._embeddings(params, batch)              # [M, n_pad, c]
        n_pad = batch.x.shape[1]
        n, mp = self.n_servers, self.m_per
        emb_g = emb.reshape((n, mp) + emb.shape[1:])       # [N, M_per, n_pad, c]
        mask_g = batch.node_mask.reshape(n, mp, n_pad)
        keys = jax.random.split(key, n + 1)
        key, server_keys = keys[0], keys[1:]
        client_ids = imputation.client_of_flat(mp, n_pad)
        (ae_params, ae_opt, as_params, as_opt, scores, idx, x_bar) = jax.vmap(
            self._server_round, in_axes=(0, 0, 0, 0, 0, 0, 0, None)
        )(server_keys, ae_params, ae_opt, as_params, as_opt, emb_g, mask_g,
          client_ids)
        scores, idx, x_bar = patcher.stitch_server_links(scores, idx, x_bar)
        batch = patcher.fix_graphs(batch, scores, idx, x_bar)
        return batch, ae_params, ae_opt, as_params, as_opt, key

    def _imputation_round_reference(self, state_tuple):
        """Sequential per-server loop (tests/benchmarks only).

        Preserves the pre-refactor structure — a Python loop running one
        server at a time — but uses the same per-server key derivation as
        :meth:`_imputation_round` (one ``split(key, N+1)`` up front, not the
        seed's chained splits), so the two are numerically equivalent and the
        equivalence test isolates exactly the loop→vmap change. Also the
        baseline the load-balance benchmark times against.
        """
        (params, batch, ae_params, ae_opt, as_params, as_opt, key) = state_tuple
        emb = self._embeddings(params, batch)              # [M, n_pad, c]
        n_pad = batch.x.shape[1]
        keys = jax.random.split(key, self.n_servers + 1)
        key, server_keys = keys[0], keys[1:]
        client_ids = imputation.client_of_flat(self.m_per, n_pad)
        outs = []
        for j in range(self.n_servers):
            sl = slice(j * self.m_per, (j + 1) * self.m_per)
            take_j = lambda t: jax.tree.map(lambda x: x[j], t)
            outs.append(self._server_round(
                server_keys[j], take_j(ae_params), take_j(ae_opt),
                take_j(as_params), take_j(as_opt), emb[sl],
                batch.node_mask[sl], client_ids))
        stack = lambda i: jax.tree.map(lambda *x: jnp.stack(x), *[o[i] for o in outs])
        ae_params, ae_opt, as_params, as_opt = (stack(i) for i in range(4))
        scores, idx, x_bar = patcher.stitch_server_links(
            stack(4), stack(5), stack(6))
        batch = patcher.fix_graphs(batch, scores, idx, x_bar)
        return batch, ae_params, ae_opt, as_params, as_opt, key

    # -- evaluation ------------------------------------------------------------

    def _evaluate(self, params, batch: ClientBatch):
        """One compiled call per round: (mean client loss, accuracy, macro-F1)."""
        def one(p, x, adj, y, node_mask, test_mask):
            logits = gnn.apply_classifier(p, self.cfg.gnn_kind, x, adj, node_mask,
                                          impl=self.aggregate_impl)
            pred = jnp.argmax(logits, axis=-1)
            mask = test_mask * (y >= 0)
            correct = jnp.sum((pred == y) * mask)
            # Macro-F1 pieces per class.
            c = self.num_classes
            onehot_p = jax.nn.one_hot(pred, c) * mask[:, None]
            onehot_y = jax.nn.one_hot(jnp.maximum(y, 0), c) * mask[:, None]
            tp = jnp.sum(onehot_p * onehot_y, axis=0)
            fp = jnp.sum(onehot_p * (1 - onehot_y), axis=0)
            fn = jnp.sum((1 - onehot_p) * onehot_y, axis=0)
            return correct, jnp.sum(mask), tp, fp, fn
        correct, total, tp, fp, fn = jax.vmap(one)(
            params, batch.x, batch.adj, batch.y, batch.node_mask, batch.test_mask)
        acc = jnp.sum(correct) / jnp.maximum(jnp.sum(total), 1.0)
        tp, fp, fn = jnp.sum(tp, 0), jnp.sum(fp, 0), jnp.sum(fn, 0)
        precision = tp / jnp.maximum(tp + fp, 1e-9)
        recall = tp / jnp.maximum(tp + fn, 1e-9)
        f1 = 2 * precision * recall / jnp.maximum(precision + recall, 1e-9)
        seen = (tp + fn) > 0
        macro_f1 = jnp.sum(jnp.where(seen, f1, 0.0)) / jnp.maximum(jnp.sum(seen), 1.0)
        loss = self._client_loss(params, batch) / self.m
        return loss, acc, macro_f1

    # -- outer loop (Algorithm 1) ----------------------------------------------

    def fit(self, key: jax.Array, batch: ClientBatch, *, rounds: Optional[int] = None
            ) -> Tuple[FGLState, Dict[str, list]]:
        state = self.init(key, batch)
        history: Dict[str, list] = {"round": [], "loss": [], "acc": [], "f1": []}
        rounds = rounds if rounds is not None else self.cfg.global_rounds
        for t_g in range(rounds):
            params, opt_state = self._local_fn(state.params, state.opt_state, state.batch)
            state.params, state.opt_state = params, opt_state
            if self.use_imputation and (t_g % self.cfg.imputation_interval == 0):
                (batch2, ae, aeo, asr, aso, key2) = self._impute_fn(
                    (state.params, state.batch, state.ae_params, state.ae_opt,
                     state.as_params, state.as_opt, state.key))
                state.batch, state.ae_params, state.ae_opt = batch2, ae, aeo
                state.as_params, state.as_opt, state.key = asr, aso, key2
            state.params = self._agg_fn(state.params)
            loss, acc, f1 = self._eval_fn(state.params, state.batch)
            history["round"].append(t_g)
            history["loss"].append(float(loss))
            history["acc"].append(float(acc))
            history["f1"].append(float(f1))
            state.round = t_g + 1
        return state, history
