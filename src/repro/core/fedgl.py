"""FGL training engine (Algorithm 1) with an explicit state lifecycle.

One engine covers every framework in the repo; the variation axes are
injected strategies (:mod:`repro.core.strategies`): a ``Topology`` maps
clients onto edge servers, an ``Aggregator`` combines client classifiers each
round, and an ``ImputationStrategy`` runs the every-K graph-fixing round.
``FedGL`` is star + FedAvg + the SpreadFGL generator; ``SpreadFGL`` is ring +
Eq. 16 + the generator; the Sec. IV-A baselines are other compositions (see
:mod:`repro.core.registry`).

Lifecycle::

    state = trainer.init(key, batch)        # fresh FGLState at round 0
    state, metrics = trainer.step(state)    # ONE global round of Algorithm 1
    state, history = trainer.fit(key, batch, rounds=30)   # thin step() loop
    state, history = trainer.fit(state=restored, rounds=10)  # true resume

``fit(state=...)`` continues at ``state.round`` — checkpoints written with
:mod:`repro.checkpoint.io` round-trip into an identical continuation (the
imputation schedule keys off the absolute round index). Per-round metrics
are accumulated as device arrays and fetched once at the end of ``fit`` —
no blocking host sync inside the loop.

Layout: client classifiers are stacked on a leading [M] axis; clients are
grouped contiguously per server so a ``[N, M_per]`` reshape recovers the edge
topology. All per-edge-server state (autoencoder, assessor, and their
optimizer states) is likewise stacked on a leading ``[N]`` axis — there are no
Python lists of per-server pytrees — and the whole imputation round is a
single ``jax.vmap`` over that axis. When an edge mesh is supplied
(``make_edge_mesh`` in ``launch/mesh.py``) the ``[N]`` axis is placed on a
JAX device mesh and the vmapped round shards across devices. Everything jits;
the outer edge-client communication loop is a Python loop (it mutates graph
structure on imputation rounds).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import assessor as assessor_lib
from repro.core import gnn, imputation, strategies
from repro.core import imputation as imputation_lib  # the ctor arg shadows it
from repro.core.types import ClientBatch, FGLConfig
from repro.optim.adam import Adam

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FGLState:
    """The full Algorithm 1 state, threaded through ``step()`` as one pytree.

    Registered dataclass so the whole state jits, checkpoints, and shards as
    a single tree — the imputation round takes and returns ``FGLState``
    directly (no positional tuples).
    """

    params: PyTree        # [M, ...] stacked client classifiers
    opt_state: Any
    ae_params: PyTree     # [N, ...] stacked per-server autoencoders
    ae_opt: Any           # [N, ...] stacked optimizer state
    as_params: PyTree     # [N, ...] stacked per-server assessors
    as_opt: Any
    batch: ClientBatch
    key: jax.Array
    round: int = 0


def _cross_entropy(logits: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7): masked CE; logits [n, c], y [n] with -1 on unlabeled."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe_y = jnp.maximum(y, 0)
    picked = jnp.take_along_axis(logp, safe_y[:, None], axis=-1)[:, 0]
    mask = mask * (y >= 0)
    return -jnp.sum(picked * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _trace_reg(params: PyTree) -> jnp.ndarray:
    """Eq. (15): Tr(W_L W_Lᵀ) = ||W_L||_F² on the last GNN layer's weights."""
    last = params["layers"][-1]
    return sum(jnp.sum(jnp.square(w)) for k, w in last.items() if k != "b")


class FGLTrainer:
    """Drives Algorithm 1 for a fixed client batch, one strategy per axis."""

    def __init__(self, cfg: FGLConfig, batch: ClientBatch,
                 *, topology: Optional[strategies.Topology] = None,
                 aggregator: Optional[strategies.Aggregator] = None,
                 imputation: Optional[strategies.ImputationStrategy] = None,
                 kernel_impl: Optional[str] = None,
                 participation: Optional[float] = None,
                 use_negative_sampling: bool = True, use_assessor: bool = True,
                 edge_mesh=None):
        if kernel_impl is not None:       # constructor override wins over cfg
            cfg = dataclasses.replace(cfg, kernel_impl=kernel_impl)
        if participation is not None:     # same: ctor override wins over cfg
            cfg = dataclasses.replace(cfg, participation=float(participation))
        if cfg.kernel_impl not in imputation_lib.KERNEL_IMPLS:
            raise ValueError(f"unknown kernel_impl {cfg.kernel_impl!r}; "
                             f"expected one of {imputation_lib.KERNEL_IMPLS}")
        if not 0.0 < cfg.participation <= 1.0:
            raise ValueError(f"participation must be in (0, 1], "
                             f"got {cfg.participation}")
        self.m = batch.num_clients
        self.topology = topology if topology is not None else strategies.StarTopology()
        layout = self.topology.build(self.m)
        self.n_servers = layout.num_servers
        self.m_per = layout.clients_per_server
        expected = np.repeat(np.arange(self.n_servers), self.m_per)
        if not np.array_equal(np.asarray(layout.server_of_client), expected):
            raise ValueError("clients must be grouped contiguously per server")
        self.cfg = cfg = dataclasses.replace(
            cfg, num_edge_servers=self.n_servers, clients_per_server=self.m_per)
        self.is_spread = self.n_servers > 1
        self.aggregator = aggregator if aggregator is not None else (
            strategies.NeighborAggregator() if self.is_spread
            else strategies.FedAvgAggregator())
        self.imputation = (imputation if imputation is not None
                           else strategies.SpreadImputation())

        self.num_classes = batch.num_classes
        self.adj_servers = jnp.asarray(layout.adjacency, jnp.float32)
        self.feature_dim = batch.x.shape[-1]
        self.kernel_impl = self.cfg.kernel_impl
        self.n_local = batch.n_local_max
        self.use_ns = use_negative_sampling
        self.use_assessor = use_assessor
        self.participation = float(cfg.participation)
        # Partial participation draws from its OWN key stream, derived from
        # cfg.seed and folded with the absolute round index: enabling ρ < 1
        # never perturbs the training key threaded through FGLState (ρ = 1
        # histories stay bit-identical), and the round-t mask is a pure
        # function of (seed, t) — a checkpoint restored mid-run reproduces
        # the participation schedule exactly, like the imputation and gossip
        # schedules.
        self._part_key = jax.random.fold_in(jax.random.key(cfg.seed), 0x9A57)
        self.opt = Adam(lr=cfg.lr_classifier)
        self.gen_opt = Adam(lr=cfg.lr_generator)
        self.edge_mesh = edge_mesh
        if edge_mesh is not None and self.n_servers % edge_mesh.size:
            raise ValueError(f"N={self.n_servers} servers must divide across the "
                             f"{edge_mesh.size}-device edge mesh")
        self._local_fn = jax.jit(self._local_rounds)
        # Round-scheduled aggregators (GossipAggregator) expose a `period`;
        # step() passes the canonicalized phase (`_agg_phase`) as a STATIC
        # arg, so jit compiles exactly 2 variants — exchange and skip — and
        # non-exchange rounds lower to zero cross-server collectives.
        # Unscheduled aggregators have period 1.
        self._agg_period = max(1, int(getattr(self.aggregator, "period", 1)))
        self._agg_fn = jax.jit(functools.partial(
            self.aggregator.aggregate, adj=self.adj_servers,
            num_servers=self.n_servers, m_per=self.m_per),
            static_argnames=("round",))
        self._impute_fn = jax.jit(functools.partial(self.imputation.impute, self))
        self._eval_fn = jax.jit(self._evaluate)

    # -- initialization ------------------------------------------------------

    def init(self, key: jax.Array, batch: ClientBatch) -> FGLState:
        """Algorithm 1 lines 1-5: a fresh ``FGLState`` at round 0."""
        cfg = self.cfg
        dims = [self.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1) + [self.num_classes]
        k_cls, k_ae, k_as, k_run = jax.random.split(key, 4)
        # Algorithm 1 line 3: all clients start from the server weights W_j.
        base = gnn.init_classifier(k_cls, cfg.gnn_kind, dims)
        params = jax.tree.map(lambda p: jnp.broadcast_to(p, (self.m,) + p.shape).copy(), base)
        ae_params = imputation.init_stacked_autoencoder(
            k_ae, self.n_servers, self.num_classes, self.feature_dim, cfg.ae_hidden)
        as_params = assessor_lib.init_stacked_assessor(
            k_as, self.n_servers, self.num_classes, cfg.assessor_hidden)
        ae_opt = jax.vmap(self.gen_opt.init)(ae_params)
        as_opt = jax.vmap(self.gen_opt.init)(as_params)
        ae_params, ae_opt, as_params, as_opt = self._shard_edge(
            (ae_params, ae_opt, as_params, as_opt))
        batch = jax.tree.map(jnp.asarray, batch)
        return FGLState(params=params, opt_state=self.opt.init(params),
                        ae_params=ae_params, ae_opt=ae_opt,
                        as_params=as_params, as_opt=as_opt,
                        batch=batch, key=k_run)

    def _shard_edge(self, tree: PyTree) -> PyTree:
        """Place the leading [N] server axis of stacked state on the edge mesh."""
        if self.edge_mesh is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec
        spec = NamedSharding(self.edge_mesh,
                             PartitionSpec(self.edge_mesh.axis_names[0]))
        return jax.tree.map(lambda x: jax.device_put(x, spec), tree)

    # -- local training (Algorithm 1 lines 8-9) ------------------------------

    def _client_loss(self, params_m: PyTree, batch: ClientBatch) -> jnp.ndarray:
        def one(params, x, adj, y, node_mask, train_mask):
            logits = gnn.apply_classifier(params, self.cfg.gnn_kind, x, adj, node_mask,
                                          impl=self.kernel_impl)
            loss = _cross_entropy(logits, y, train_mask)
            if self.is_spread and self.cfg.trace_reg > 0:
                loss = loss + self.cfg.trace_reg * _trace_reg(params)
            return loss
        losses = jax.vmap(one)(params_m, batch.x, batch.adj, batch.y,
                               batch.node_mask, batch.train_mask)
        return jnp.sum(losses)  # sum => per-client grads stay independent

    def _local_rounds(self, params, opt_state, batch: ClientBatch):
        def step(carry, _):
            params, opt_state = carry
            grads = jax.grad(self._client_loss)(params, batch)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), ()
        (params, opt_state), _ = jax.lax.scan(step, (params, opt_state), None,
                                              length=self.cfg.local_rounds)
        return params, opt_state

    # -- aggregation (strategy) ----------------------------------------------

    def _agg_phase(self, t: int) -> int:
        """Canonical static phase for the jitted aggregation call.

        Only two behaviors exist — exchange round or skip round — so the
        phase is canonicalized to ``period - 1`` (exchange) or ``0`` (skip):
        exactly 2 compiled variants regardless of K, instead of one cache
        entry per distinct ``t % period``.

        Buffered aggregators (:class:`strategies.AsyncAggregator`) expose a
        ``phase(t, m)`` hook instead of a fixed period — their flush schedule
        is data-independent but not periodic. The hook still returns only
        0/1, so jit still sees exactly 2 variants.
        """
        hook = getattr(self.aggregator, "phase", None)
        if hook is not None:
            return int(hook(t, self.m))
        p = self._agg_period
        return p - 1 if (t + 1) % p == 0 else 0

    def _agg_mask(self, t: int):
        """The [M] weight vector of round ``t``'s aggregation, or None.

        Composes the two per-round weight sources: the participation mask
        (ρ < 1) and, for buffered aggregators exposing ``round_weights(t,
        m)``, the staleness-discount weights of the flush. Both are pure
        functions of (cfg.seed, t), so the composition is too. A client
        sampled out by ρ < 1 contributes zero weight even if its (stale)
        update sits in the buffer.
        """
        mask = self._participation_mask(t)
        hook = getattr(self.aggregator, "round_weights", None)
        if hook is None:
            return mask
        weights = hook(t, self.m)
        if weights is None or mask is None:
            return weights if weights is not None else mask
        return weights * mask

    def _participation_mask(self, t: int):
        """[M] 0/1 participation mask of round ``t``, or None at ρ = 1.

        None (full participation) routes the aggregators onto their exact
        unmasked code paths, so ρ = 1 reproduces pre-participation fixed-seed
        goldens bit-identically. At ρ < 1 the mask has a static [M] shape
        every round (exactly ceil(ρ·M) participants, never a gather/resize),
        so the jitted aggregation compiles exactly one masked variant.
        """
        if self.participation >= 1.0:
            return None
        key = jax.random.fold_in(self._part_key, t)
        return strategies.participation_mask(key, self.m, self.participation)

    def aggregate(self, params: PyTree, *, round: int = 0, mask=None) -> PyTree:
        """Apply this trainer's Aggregator to stacked client classifiers.

        ``round`` matters only for round-scheduled aggregators (gossip every
        K); it is canonicalized to the exchange/skip phase before the jitted
        call. ``mask`` is an optional [M] participation mask (``step()``
        passes the round's sampled mask when ``cfg.participation < 1``).
        """
        if mask is None:
            mask = self._agg_mask(int(round))
        return self._agg_fn(params, round=self._agg_phase(int(round)),
                            mask=mask)

    # -- imputation helpers shared by the strategies --------------------------

    def _embeddings(self, params, batch: ClientBatch) -> jnp.ndarray:
        def one(p, x, adj, mask):
            logits = gnn.apply_classifier(p, self.cfg.gnn_kind, x, adj, mask,
                                          impl=self.kernel_impl)
            return jax.nn.softmax(logits, axis=-1)
        return jax.vmap(one)(params, batch.x, batch.adj, batch.node_mask)

    def _train_generator(self, key, ae, ae_opt, asr, as_opt, h_real, flat_mask):
        """Alternating AE / assessor training (Algorithm 1 lines 16-23).

        The noise matrix S is sampled ONCE per imputation round (the only
        randomness here) and held fixed across AE/assessor iterations, so
        that row v of S is bound to node v: the masked reconstruction term of
        Eq. (14) then makes h(f(S))_v track h_v and the encoder output
        X̅_v = f(S)_v is a node-specific imputed feature (Sec. III-C: "X̅ =
        f(S) indicates the potential features"). The per-iteration scans are
        deliberately keyless — S is NOT resampled per iteration.
        Returns (ae, ae_opt, asr, as_opt, s_noise).
        """
        cfg = self.cfg
        theta = cfg.theta(self.num_classes)
        n = h_real.shape[0]
        e = (assessor_lib.negative_mask(h_real, theta) if self.use_ns
             else jnp.ones_like(h_real))
        _, ks = jax.random.split(key)
        s_noise = imputation.sample_noise(ks, n, self.num_classes)

        def ae_step(carry, _):
            ae, ae_opt = carry
            s = s_noise
            if self.use_assessor:
                loss_fn = lambda p: assessor_lib.autoencoder_loss(
                    p, asr_current[0], s, h_real, e, flat_mask)
            else:
                # w/o assessor: plain masked reconstruction of H (Fig. 7 ablation).
                def loss_fn(p):
                    _, h_fake = imputation.reconstruct(p, s)
                    diff = (h_real - h_fake)
                    return jnp.sum(jnp.sum(diff * diff, -1) * flat_mask) / jnp.maximum(
                        jnp.sum(flat_mask), 1.0)
            grads = jax.grad(loss_fn)(ae)
            ae, ae_opt = self.gen_opt.update(grads, ae_opt, ae)
            return (ae, ae_opt), ()

        def as_step(carry, _):
            asr, as_opt = carry
            _, h_fake = imputation.reconstruct(ae_current[0], s_noise)
            if self.use_ns:
                loss_fn = lambda p: assessor_lib.assessor_loss(p, h_real, h_fake, e, flat_mask)
            else:
                loss_fn = lambda p: assessor_lib.assessor_loss_plain(p, h_real, h_fake, flat_mask)
            grads = jax.grad(loss_fn)(asr)
            asr, as_opt = self.gen_opt.update(grads, as_opt, asr)
            return (asr, as_opt), ()

        for _ in range(cfg.ae_outer_iters):
            asr_current = (asr, as_opt)
            (ae, ae_opt), _ = jax.lax.scan(ae_step, (ae, ae_opt), None,
                                           length=cfg.ae_iters)
            ae_current = (ae, ae_opt)
            if self.use_assessor:
                (asr, as_opt), _ = jax.lax.scan(as_step, (asr, as_opt), None,
                                                length=cfg.assessor_iters)
        return ae, ae_opt, asr, as_opt, s_noise

    def _server_round_gen(self, key_j, ae, aeo, asr, aso, emb_j, mask_j):
        """The generator half of one server's imputation round.

        Fusion + adversarial AE/assessor training + X̅ = f(S); everything
        EXCEPT the similarity top-k, so the candidate-sharded path
        (``SpreadImputation.sim_mesh``) can vmap this part over the [N]
        server axis and run ONE batched ring top-k outside the vmap —
        shard_map-over-vmap is the fragile composition, vmap-then-shard_map
        is not. Returns the fused (h_flat, flat_mask) along with the trained
        state so the caller computes ``target_mask`` and similarity from the
        exact same fused embeddings.
        """
        h_flat, flat_mask = imputation.fuse_embeddings(emb_j, mask_j)
        ae, aeo, asr, aso, s_noise = self._train_generator(
            key_j, ae, aeo, asr, aso, h_flat, flat_mask)
        x_bar = imputation.encode(ae, s_noise)              # X̅ = f(S), same S
        return ae, aeo, asr, aso, x_bar, h_flat, flat_mask

    def _server_round(self, key_j, ae, aeo, asr, aso, emb_j, mask_j, client_ids):
        """One edge server's imputation work on its [M_per, n_pad, c] slice."""
        cfg = self.cfg
        ae, aeo, asr, aso, x_bar, h_flat, flat_mask = self._server_round_gen(
            key_j, ae, aeo, asr, aso, emb_j, mask_j)
        # Link targets must be REAL local nodes: after the first fixing round
        # the patcher sets node_mask=1 on aug slots, and without this
        # restriction later rounds could link to synthetic nodes.
        target_mask = flat_mask * imputation.local_slot_mask(
            self.m_per, emb_j.shape[1], self.n_local)
        scores, idx = imputation.similarity_topk(
            h_flat, flat_mask, client_ids, cfg.top_k_links,
            kernel_impl=self.kernel_impl, target_mask=target_mask)
        return ae, aeo, asr, aso, scores, idx, x_bar

    def _imputation_round_reference(self, state: FGLState) -> FGLState:
        """Sequential oracle of the vmapped generator round (tests/benchmarks).

        Only meaningful when this trainer's imputation strategy exposes a
        reference implementation (``SpreadImputation`` does).
        """
        return self.imputation.impute_reference(self, state)

    # -- evaluation ------------------------------------------------------------

    def _evaluate(self, params, batch: ClientBatch):
        """One compiled call per round: (mean client loss, accuracy, macro-F1)."""
        def one(p, x, adj, y, node_mask, test_mask):
            logits = gnn.apply_classifier(p, self.cfg.gnn_kind, x, adj, node_mask,
                                          impl=self.kernel_impl)
            pred = jnp.argmax(logits, axis=-1)
            mask = test_mask * (y >= 0)
            correct = jnp.sum((pred == y) * mask)
            # Macro-F1 pieces per class.
            c = self.num_classes
            onehot_p = jax.nn.one_hot(pred, c) * mask[:, None]
            onehot_y = jax.nn.one_hot(jnp.maximum(y, 0), c) * mask[:, None]
            tp = jnp.sum(onehot_p * onehot_y, axis=0)
            fp = jnp.sum(onehot_p * (1 - onehot_y), axis=0)
            fn = jnp.sum((1 - onehot_p) * onehot_y, axis=0)
            return correct, jnp.sum(mask), tp, fp, fn
        correct, total, tp, fp, fn = jax.vmap(one)(
            params, batch.x, batch.adj, batch.y, batch.node_mask, batch.test_mask)
        acc = jnp.sum(correct) / jnp.maximum(jnp.sum(total), 1.0)
        tp, fp, fn = jnp.sum(tp, 0), jnp.sum(fp, 0), jnp.sum(fn, 0)
        precision = tp / jnp.maximum(tp + fp, 1e-9)
        recall = tp / jnp.maximum(tp + fn, 1e-9)
        f1 = 2 * precision * recall / jnp.maximum(precision + recall, 1e-9)
        seen = (tp + fn) > 0
        macro_f1 = jnp.sum(jnp.where(seen, f1, 0.0)) / jnp.maximum(jnp.sum(seen), 1.0)
        loss = self._client_loss(params, batch) / self.m
        return loss, acc, macro_f1

    def evaluate(self, state: FGLState) -> Dict[str, jnp.ndarray]:
        """Metrics of the current state (device arrays, no host sync)."""
        loss, acc, f1 = self._eval_fn(state.params, state.batch)
        return {"loss": loss, "acc": acc, "f1": f1}

    # -- outer loop (Algorithm 1) ----------------------------------------------

    def step(self, state: FGLState) -> Tuple[FGLState, Dict[str, Any]]:
        """One global round of Algorithm 1 (lines 6-26).

        Local training, the strategy's imputation round when the absolute
        round index hits the every-K schedule, aggregation, then evaluation.
        Returns a new state at ``round + 1`` and metrics as device arrays
        (``{"round", "loss", "acc", "f1"}``) — callers decide when to sync.
        """
        t = int(state.round)
        state = dataclasses.replace(state)   # never mutate the caller's state
        state.params, state.opt_state = self._local_fn(
            state.params, state.opt_state, state.batch)
        if self.imputation.active and (t % self.cfg.imputation_interval == 0):
            state = self._impute_fn(state)
        # The gossip phase, the participation mask, and the async flush
        # schedule are pure functions of the absolute round, so a state
        # restored mid-interval (or mid-buffer) resumes every schedule
        # exactly where the checkpoint left it.
        state.params = self._agg_fn(state.params, round=self._agg_phase(t),
                                    mask=self._agg_mask(t))
        loss, acc, f1 = self._eval_fn(state.params, state.batch)
        state.round = t + 1
        return state, {"round": t, "loss": loss, "acc": acc, "f1": f1}

    def fit(self, key: Optional[jax.Array] = None,
            batch: Optional[ClientBatch] = None, *,
            state: Optional[FGLState] = None, rounds: Optional[int] = None
            ) -> Tuple[FGLState, Dict[str, list]]:
        """Run ``rounds`` global rounds (default ``cfg.global_rounds``).

        Either pass ``(key, batch)`` for a fresh run, or ``state=`` (e.g. a
        checkpoint restored via :func:`repro.checkpoint.io.restore`) to
        resume — the loop continues at ``state.round`` with the imputation
        schedule intact. Metrics stay on device for the whole loop and are
        fetched with a single transfer at the end.
        """
        if state is None:
            if key is None or batch is None:
                raise ValueError("fit() needs (key, batch) for a fresh run "
                                 "or state= to resume")
            state = self.init(key, batch)
        else:
            if key is not None or batch is not None:
                raise ValueError("fit(state=...) resumes from the state's own "
                                 "key/batch; do not also pass key or batch")
            state = dataclasses.replace(state, round=int(state.round))
            # A restored checkpoint holds host arrays: put the stacked [N]
            # generator state back on the edge mesh before the vmapped round.
            (state.ae_params, state.ae_opt, state.as_params,
             state.as_opt) = self._shard_edge(
                (state.ae_params, state.ae_opt, state.as_params, state.as_opt))
        rounds = rounds if rounds is not None else self.cfg.global_rounds
        metrics = []
        for _ in range(rounds):
            state, m = self.step(state)
            metrics.append(m)
        metrics = jax.device_get(metrics)    # ONE host sync for the whole run
        history: Dict[str, list] = {
            "round": [int(m["round"]) for m in metrics],
            "loss": [float(m["loss"]) for m in metrics],
            "acc": [float(m["acc"]) for m in metrics],
            "f1": [float(m["f1"]) for m in metrics],
        }
        return state, history
