"""The paper's contribution: FedGL/SpreadFGL training engines, the adaptive
graph imputation generator + versatile assessor + negative sampling
(Sec. III), graph fixing, comparison baselines, and the Eq. 16 gossip
aggregation both at the edge layer and on the TPU pod axis."""
