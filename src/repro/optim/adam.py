"""Minimal functional optimizers (no optax offline).

Adam/AdamW/SGD over arbitrary pytrees, plus global-norm clipping and LR
schedules. State is a pytree-of-pytrees so it shards with the same
PartitionSpecs as the parameters (sharding/specs.py adds ZeRO-1 style extra
sharding for the large LM configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray   # scalar int32
    mu: PyTree          # first moment (like params)
    nu: PyTree          # second moment (like params)


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None

    def init(self, params: PyTree) -> AdamState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                         nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads: PyTree, state: AdamState, params: PyTree
               ) -> Tuple[PyTree, AdamState]:
        """Returns (new_params, new_state)."""
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr * (self.schedule(step) if self.schedule is not None else 1.0)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 0.01
    momentum: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params: PyTree):
        if self.momentum:
            return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return ()

    def update(self, grads, state, params):
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        if self.momentum:
            state = jax.tree.map(lambda s, g: self.momentum * s + g.astype(jnp.float32),
                                 state, grads)
            eff = state
        else:
            eff = grads
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - self.lr * g).astype(p.dtype),
            params, eff)
        return new_params, state


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn
