"""Functional optimizers (Adam/SGD), clipping, LR schedules."""
from repro.optim.adam import Adam, SGD, cosine_schedule  # noqa: F401
