"""Training step factory: LM loss, grad accumulation, gossip aggregation."""
