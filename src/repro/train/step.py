"""Training step factory: loss, grads, optimizer update, gossip aggregation.

``make_train_step(cfg, optimizer)`` returns a pure function
``step(state, batch) -> (state, metrics)`` suitable for jit/pjit. ``batch`` is
{"tokens": [B, S], plus "memory" for audio/vlm archs}; next-token LM loss with
the MoE aux loss added.

``aggregation="spread"`` applies the paper's Eq. 16 as a *cross-pod gossip*:
instead of letting pjit all-reduce gradients over the ``pod`` mesh axis every
step, gradients stay pod-local and parameters are averaged with ring neighbors
every K steps (core/gossip.py). This is SpreadFGL's edge-layer aggregation
lifted to the TPU mesh — see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.adam import Adam, AdamState

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: AdamState
    step: jnp.ndarray


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux)."""
    from repro.sharding.constraints import constrain
    tokens = batch["tokens"]
    logits, aux = transformer.forward(params, cfg, tokens,
                                      memory=batch.get("memory"))
    targets = tokens[:, 1:]
    logits = constrain(logits[:, :-1], "batch", None, "vocab")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    nll = constrain(nll, "batch", None)
    loss = jnp.mean(nll)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux}


def init_state(key, cfg: ModelConfig, optimizer: Adam) -> TrainState:
    params = transformer.init_model(key, cfg)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, optimizer: Adam, *,
                    aggregation: str = "allreduce",
                    gossip_every: int = 1,
                    pod_axis: Optional[str] = None,
                    microbatch: int = 1
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """aggregation: "allreduce" (classic) | "spread" (paper's Eq. 16 gossip).

    With "spread", callers run the step inside shard_map over the pod axis and
    must pass ``pod_axis``; gradients are NOT psum'd across pods — instead
    parameters gossip with ring neighbors every ``gossip_every`` steps.

    ``microbatch`` > 1 splits the batch on dim 0 into that many chunks and
    accumulates gradients over a lax.scan — bounds peak activation memory by
    a 1/microbatch factor at the cost of serialized steps (§Perf lever for
    the memory-dominated training shapes).
    """

    def _grads(params, batch):
        return jax.value_and_grad(lm_loss, has_aux=True)(params, cfg, batch)

    def _accumulated_grads(params, batch):
        n = microbatch
        split = {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
                 for k, v in batch.items()}

        def body(carry, micro):
            gacc, tacc, lacc, aacc = carry
            (total, metrics), grads = _grads(params, micro)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (gacc, tacc + total, lacc + metrics["loss"],
                    aacc + metrics["aux"]), ()

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gacc, total, loss, aux), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0, 0.0), split)
        inv = 1.0 / n
        grads = jax.tree.map(lambda g: g * inv, gacc)
        return (total * inv, {"loss": loss * inv, "aux": aux * inv}), grads

    def step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatch > 1:
            (total, metrics), grads = _accumulated_grads(state.params, batch)
        else:
            (total, metrics), grads = _grads(state.params, batch)
        if aggregation == "spread" and pod_axis is not None:
            from repro.core import gossip
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params)
            params = gossip.maybe_gossip(params, state.step, pod_axis,
                                         every=gossip_every)
        else:
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params)
        metrics = dict(metrics, total=total)
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1), metrics

    return step
