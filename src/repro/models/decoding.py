"""Serving paths: prefill (prompt -> cache) and decode_step (1 token + cache).

Cache layout is a per-layer python list (static length), so heterogeneous
layers (windowed ring buffers vs full-length KV, mamba/mLSTM/sLSTM states)
coexist. ``decode_step`` unrolls the layer loop — per-layer decode graphs are
tiny, and unrolling lets each layer index its static slice of the grouped
parameter stacks.

Windowed layers keep a ring buffer of ``window`` slots; after prefill the last
``window`` kv entries are rolled into ring order so decode can continue with
``slot = pos % window``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.config import ModelConfig
from repro.models.transformer import (_block_kind, _encode_memory,
                                      apply_cross_block, group_size)

PyTree = Any


def _layer_params(params: PyTree, cfg: ModelConfig, layer: int) -> PyTree:
    """Static slice of the grouped stacks for one layer."""
    if cfg.arch_type == "ssm":
        return params["blocks"][layer]
    g = group_size(cfg)
    gi, r = layer // g, layer % g
    return jax.tree.map(lambda t: t[gi], params["blocks"][r])


def _cross_params(params: PyTree, cfg: ModelConfig, gi: int) -> PyTree:
    return jax.tree.map(lambda t: t[gi], params["cross_blocks"])


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               memory: Optional[jnp.ndarray] = None) -> PyTree:
    """Zeroed cache sized for a maximum context of ``seq_len``."""
    dt = jnp.dtype(cfg.dtype)
    ws = cfg.windows
    layers: List[PyTree] = []
    for i in range(cfg.num_layers):
        kind = _block_kind(cfg, i)
        entry: Dict[str, PyTree] = {}
        if kind in ("attn", "hybrid", "encdec_dec"):
            entry.update(A.init_kv_cache(batch, cfg.num_kv_heads, cfg.head_dim,
                                         seq_len=seq_len, window=ws[i], dtype=dt))
        if kind == "hybrid":
            entry.update(S.init_mamba_state(batch, cfg.d_model,
                                            expand=cfg.ssm_expand,
                                            state=cfg.ssm_state))
        if kind == "mlstm":
            entry.update(X.init_mlstm_state(batch, cfg.d_model, cfg.num_heads,
                                            expand=cfg.ssm_expand))
        if kind == "slstm":
            entry.update(X.init_slstm_state(batch, cfg.d_model))
        layers.append(entry)
    cache: Dict[str, PyTree] = {"layers": layers,
                                "pos": jnp.zeros((), jnp.int32)}
    if memory is not None:
        cache["memory"] = memory
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def decode_step(params: PyTree, cfg: ModelConfig, cache: PyTree,
                token: jnp.ndarray) -> Tuple[jnp.ndarray, PyTree]:
    """token: [B, 1] int32 -> (logits [B, V] f32, updated cache)."""
    x = L.embed_tokens(params["embed"], token)
    pos = cache["pos"]
    memory = cache.get("memory")
    if cfg.is_encdec:
        pos_table = params["embed"]["positions"]
        x = x + jnp.take(pos_table, pos % pos_table.shape[0], axis=0)[None, None]
    ws = cfg.windows
    g = group_size(cfg)
    new_layers: List[PyTree] = []
    aux_kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                  head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)

    for i in range(cfg.num_layers):
        kind = _block_kind(cfg, i)
        bp = _layer_params(params, cfg, i)
        entry = cache["layers"][i]
        new_entry: Dict[str, PyTree] = {}
        if kind in ("attn", "hybrid", "encdec_dec"):
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            attn_out, kv = A.decode_self_attention(
                bp["attn"], h, {"k": entry["k"], "v": entry["v"]}, pos,
                window=ws[i], qk_norm=cfg.qk_norm,
                use_rope=not cfg.is_encdec, **aux_kw)
            new_entry.update(kv)
            if kind == "hybrid":
                mamba_out, hstate = S.decode_mamba(bp["mamba"], h,
                                                   {"h": entry["h"]},
                                                   state=cfg.ssm_state)
                attn_out = 0.5 * (attn_out + mamba_out)
                new_entry.update(hstate)
            x = x + attn_out
            if kind == "encdec_dec":
                h = L.apply_norm(bp["ln_cross"], x, cfg.norm_kind)
                x = x + A.cross_attention(bp["cross"], h, memory,
                                          num_heads=cfg.num_heads,
                                          num_kv_heads=cfg.num_kv_heads,
                                          head_dim=cfg.head_dim)
            h = L.apply_norm(bp["ln2"], x, cfg.norm_kind)
            if cfg.is_moe:
                ff, _ = M.apply_moe(bp["moe"], h, num_experts=cfg.num_experts,
                                    top_k=cfg.experts_per_token,
                                    capacity_factor=cfg.capacity_factor,
                                    act=cfg.act)
            else:
                ff = L.apply_mlp(bp["mlp"], h, cfg.act)
            x = x + ff
        elif kind == "mlstm":
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            out, st = X.decode_mlstm(bp["mlstm"], h, entry, cfg.num_heads)
            x = x + out
            new_entry.update(st)
        elif kind == "slstm":
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            out, st = X.decode_slstm(bp["slstm"], h, entry)
            x = x + out
            new_entry.update(st)
        new_layers.append(new_entry)
        # VLM gated cross-attention at group boundaries.
        if cfg.cross_attn_interval and (i + 1) % g == 0:
            cp = _cross_params(params, cfg, i // g)
            x = apply_cross_block(cp, x, memory, cfg)

    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind)
    logits = L.unembed(params["embed"], x, softcap=cfg.logit_softcap)
    new_cache = dict(cache, layers=new_layers, pos=pos + 1)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray, *,
            max_len: Optional[int] = None,
            memory: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, PyTree]:
    """tokens [B, S] -> (last-position logits [B, V], decode-ready cache).

    ``max_len``: total context budget the cache must hold (>= S); defaults S.
    """
    from repro.sharding.constraints import constrain
    seq_ax = "seq" if cfg.seq_parallel_activations else None
    b, s = tokens.shape
    max_len = max_len or s
    x = L.embed_tokens(params["embed"], tokens)
    x = constrain(x, "batch", seq_ax, None)
    if cfg.is_encdec:
        pos_table = params["embed"]["positions"]
        x = x + jnp.take(pos_table, jnp.arange(s) % pos_table.shape[0], axis=0)[None]
        memory = _encode_memory(params, cfg, memory)
    ws = cfg.windows
    g = group_size(cfg)
    cache = init_cache(cfg, b, max_len, memory=memory)
    attn_kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                   head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)

    def run_layer(bp, x, i):
        """Returns (x, cache_entry)."""
        kind = _block_kind(cfg, i)
        entry: Dict[str, PyTree] = {}
        if kind in ("attn", "hybrid", "encdec_dec"):
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            attn_out, k, v = A.self_attention_kv(
                bp["attn"], h, window=ws[i], qk_norm=cfg.qk_norm,
                impl=cfg.attention_impl, use_rope=not cfg.is_encdec, **attn_kw)
            entry["k"], entry["v"] = k, v
            if kind == "hybrid":
                mamba_out, hstate = S.apply_mamba(bp["mamba"], h,
                                                  state=cfg.ssm_state,
                                                  return_state=True)
                attn_out = 0.5 * (attn_out + mamba_out)
                entry.update(hstate)
            x = x + attn_out
            if kind == "encdec_dec":
                h = L.apply_norm(bp["ln_cross"], x, cfg.norm_kind)
                x = x + A.cross_attention(bp["cross"], h, memory,
                                          num_heads=cfg.num_heads,
                                          num_kv_heads=cfg.num_kv_heads,
                                          head_dim=cfg.head_dim)
            h = L.apply_norm(bp["ln2"], x, cfg.norm_kind)
            if cfg.is_moe:
                ff, _ = M.apply_moe(bp["moe"], h, num_experts=cfg.num_experts,
                                    top_k=cfg.experts_per_token,
                                    capacity_factor=cfg.capacity_factor,
                                    act=cfg.act)
            else:
                ff = L.apply_mlp(bp["mlp"], h, cfg.act)
            x = x + ff
        elif kind == "mlstm":
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            out, st = X.apply_mlstm(bp["mlstm"], h, cfg.num_heads,
                                    return_state=True)
            x = x + out
            entry.update(st)
        elif kind == "slstm":
            h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
            out, st = X.apply_slstm(bp["slstm"], h, cfg.num_heads,
                                    return_state=True)
            x = x + out
            entry.update(st)
        return x, entry

    if cfg.arch_type == "ssm":
        entries = []
        for i, bp in enumerate(params["blocks"]):
            x, entry = run_layer(bp, x, i)
            entries.append(entry)
    else:
        has_cross = bool(cfg.cross_attn_interval)

        def body(x, xs):
            x = constrain(x, "batch", seq_ax, None)
            blocks = xs[:g]
            cross = xs[g] if has_cross else None
            group_entries = []
            for r in range(g):
                x, entry = run_layer(blocks[r], x, r)
                group_entries.append(entry)
            if has_cross:
                x = apply_cross_block(cross, x, memory, cfg)
            return x, tuple(group_entries)

        xs = tuple(params["blocks"])
        if has_cross:
            xs = xs + (params["cross_blocks"],)
        fn = jax.checkpoint(body) if cfg.remat else body
        x, ys = jax.lax.scan(fn, x, xs)
        # ys[r] leaves have leading n_groups; regroup per layer.
        entries = []
        for i in range(cfg.num_layers):
            gi, r = i // g, i % g
            entries.append(jax.tree.map(lambda t: t[gi], ys[r]))

    # Convert stacked prefill kv into decode cache layout.
    for i, entry in enumerate(entries):
        tgt = cache["layers"][i]
        if "k" in entry:
            size = tgt["k"].shape[2]
            k, v = entry["k"], entry["v"]
            if size >= s:  # global (or window >= prompt): plain left-aligned
                tgt["k"] = jax.lax.dynamic_update_slice_in_dim(tgt["k"], k, 0, 2)
                tgt["v"] = jax.lax.dynamic_update_slice_in_dim(tgt["v"], v, 0, 2)
            else:  # ring buffer: keep last `size`, rolled to slot order
                ksl, vsl = k[:, :, s - size:], v[:, :, s - size:]
                shift = s % size
                tgt["k"] = jnp.roll(ksl, shift, axis=2)
                tgt["v"] = jnp.roll(vsl, shift, axis=2)
        for key2 in ("h", "c", "n", "m"):
            if key2 in entry:
                tgt[key2] = entry[key2]
    cache["pos"] = jnp.asarray(s, jnp.int32)

    x_last = x[:, -1]
    x_last = L.apply_norm(params["final_norm"], x_last, cfg.norm_kind)
    logits = L.unembed(params["embed"], x_last, softcap=cfg.logit_softcap)
    return logits, cache
