"""Model assembly for all six architecture families.

Layer stacks are scanned over *repeating groups*: the per-layer heterogeneity
of every assigned arch is periodic (gemma3's 5 local : 1 global pattern has
period 6; llama-vision inserts a cross-attention block every 5 layers; dense
stacks have period 1), so parameters are stored as a tuple of ``group_size``
stacked trees, each with leading dim ``num_groups``, and lax.scan runs over
groups with a statically-unrolled inner loop over the group. This keeps
compile time O(group) while letting every layer keep a static window size
(required by the Pallas flash kernel).

The xlstm family (12 distinct small layers) uses an unrolled list instead.

Three entry points per model:
- ``forward``      : [B, S] tokens -> logits (training).
- ``prefill``      : tokens -> (last-position logits, per-layer decode cache).
- ``decode_step``  : one token + cache -> (logits, cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.config import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------

def group_size(cfg: ModelConfig) -> int:
    """Smallest period covering window pattern + cross-attn insertion."""
    if cfg.arch_type == "ssm":
        return cfg.num_layers  # unrolled
    ws = cfg.windows
    period = 1
    for p in range(1, cfg.num_layers + 1):
        if cfg.num_layers % p:
            continue
        if all(ws[i] == ws[i % p] for i in range(cfg.num_layers)):
            period = p
            break
    if cfg.cross_attn_interval:
        # group must end exactly where a cross block goes
        period = _lcm(period, cfg.cross_attn_interval)
    return period


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Block init / axes / apply
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_block(key, cfg: ModelConfig, kind: str) -> PyTree:
    """kind: attn | hybrid | encdec_dec | encoder | mlstm | slstm."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Dict[str, PyTree] = {}
    if kind in ("attn", "hybrid", "encdec_dec", "encoder"):
        p["ln1"] = L.init_norm(cfg.norm_kind, d, dt)
        p["attn"] = A.init_attention(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                     cfg.head_dim, qk_norm=cfg.qk_norm,
                                     use_bias=cfg.use_bias, dtype=dt)
        p["ln2"] = L.init_norm(cfg.norm_kind, d, dt)
        if cfg.is_moe:
            p["moe"] = M.init_moe(ks[1], d, cfg.d_ff, cfg.num_experts, cfg.act, dt)
        else:
            p["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff, cfg.act, cfg.use_bias, dt)
    if kind == "hybrid":
        p["mamba"] = S.init_mamba(ks[2], d, expand=cfg.ssm_expand,
                                  state=cfg.ssm_state, dtype=dt)
    if kind == "encdec_dec":
        p["ln_cross"] = L.init_norm(cfg.norm_kind, d, dt)
        p["cross"] = A.init_attention(ks[3], d, cfg.num_heads, cfg.num_kv_heads,
                                      cfg.head_dim, qk_norm=False,
                                      use_bias=cfg.use_bias, dtype=dt)
    if kind == "mlstm":
        p["ln1"] = L.init_norm(cfg.norm_kind, d, dt)
        p["mlstm"] = X.init_mlstm(ks[0], d, cfg.num_heads,
                                  expand=cfg.ssm_expand, dtype=dt)
    if kind == "slstm":
        p["ln1"] = L.init_norm(cfg.norm_kind, d, dt)
        p["slstm"] = X.init_slstm(ks[0], d, cfg.num_heads, dtype=dt)
    return p


def axes_block(cfg: ModelConfig, kind: str) -> PyTree:
    p: Dict[str, PyTree] = {}
    if kind in ("attn", "hybrid", "encdec_dec", "encoder"):
        p["ln1"] = L.axes_norm(cfg.norm_kind)
        p["attn"] = A.axes_attention(qk_norm=cfg.qk_norm, use_bias=cfg.use_bias)
        p["ln2"] = L.axes_norm(cfg.norm_kind)
        if cfg.is_moe:
            p["moe"] = M.axes_moe(cfg.act)
        else:
            p["mlp"] = L.axes_mlp(cfg.act, cfg.use_bias)
    if kind == "hybrid":
        p["mamba"] = S.axes_mamba()
    if kind == "encdec_dec":
        p["ln_cross"] = L.axes_norm(cfg.norm_kind)
        p["cross"] = A.axes_attention(qk_norm=False, use_bias=cfg.use_bias)
    if kind == "mlstm":
        p["ln1"] = L.axes_norm(cfg.norm_kind)
        p["mlstm"] = X.axes_mlstm()
    if kind == "slstm":
        p["ln1"] = L.axes_norm(cfg.norm_kind)
        p["slstm"] = X.axes_slstm()
    return p


def apply_block(bp: PyTree, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                window: int, memory: Optional[jnp.ndarray] = None,
                causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    use_rope = not cfg.is_encdec
    if kind in ("attn", "hybrid", "encdec_dec", "encoder"):
        h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
        if kind == "encoder" or not causal:
            q, k, v = A._project_qkv(bp["attn"], h, h, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, cfg.qk_norm)
            attn_out = A._sdpa(q, k, v, causal=False, window=0)
            b, s = x.shape[0], x.shape[1]
            attn_out = attn_out.transpose(0, 2, 1, 3).reshape(b, s, -1)
            attn_out = attn_out @ bp["attn"]["wo"] + bp["attn"].get("bo", 0.0)
        else:
            attn_out = A.self_attention(
                bp["attn"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
                window=window, rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                impl=cfg.attention_impl, use_rope=use_rope)
        if kind == "hybrid":
            mamba_out = S.apply_mamba(bp["mamba"], h, state=cfg.ssm_state)
            attn_out = 0.5 * (attn_out + mamba_out)  # parallel heads (hymba)
        x = x + attn_out
        if kind == "encdec_dec":
            h = L.apply_norm(bp["ln_cross"], x, cfg.norm_kind)
            x = x + A.cross_attention(bp["cross"], h, memory,
                                      num_heads=cfg.num_heads,
                                      num_kv_heads=cfg.num_kv_heads,
                                      head_dim=cfg.head_dim)
        h = L.apply_norm(bp["ln2"], x, cfg.norm_kind)
        if cfg.is_moe:
            ff, aux = M.apply_moe(bp["moe"], h, num_experts=cfg.num_experts,
                                  top_k=cfg.experts_per_token,
                                  capacity_factor=cfg.capacity_factor, act=cfg.act)
        else:
            ff = L.apply_mlp(bp["mlp"], h, cfg.act)
        x = x + ff
    elif kind == "mlstm":
        h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
        x = x + X.apply_mlstm(bp["mlstm"], h, cfg.num_heads)
    elif kind == "slstm":
        h = L.apply_norm(bp["ln1"], x, cfg.norm_kind)
        x = x + X.apply_slstm(bp["slstm"], h, cfg.num_heads)
    else:
        raise ValueError(kind)
    return x, aux


def init_cross_block(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    return {"ln": L.init_norm(cfg.norm_kind, cfg.d_model, dt),
            "attn": A.init_attention(key, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim,
                                     qk_norm=False, use_bias=cfg.use_bias, dtype=dt),
            "gate": jnp.zeros((), dt)}


def axes_cross_block(cfg: ModelConfig) -> PyTree:
    return {"ln": L.axes_norm(cfg.norm_kind),
            "attn": A.axes_attention(qk_norm=False, use_bias=cfg.use_bias),
            "gate": ()}


def apply_cross_block(cp: PyTree, x: jnp.ndarray, memory: jnp.ndarray,
                      cfg: ModelConfig) -> jnp.ndarray:
    """Gated image cross-attention (llama-3.2-vision style)."""
    h = L.apply_norm(cp["ln"], x, cfg.norm_kind)
    out = A.cross_attention(cp["attn"], h, memory, num_heads=cfg.num_heads,
                            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim)
    return x + jnp.tanh(cp["gate"]) * out


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def _block_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.arch_type == "ssm":
        return cfg.block_pattern[layer_idx] if cfg.block_pattern else "mlstm"
    if cfg.arch_type == "hybrid":
        return "hybrid"
    if cfg.is_encdec:
        return "encdec_dec"
    return "attn"


def init_model(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    max_pos = cfg.max_target_positions if cfg.is_encdec else 0
    params: Dict[str, PyTree] = {
        "embed": L.init_embed(keys[0], cfg.vocab_size, cfg.d_model, dt,
                              tie=cfg.tie_embeddings, max_positions=max_pos),
        "final_norm": L.init_norm(cfg.norm_kind, cfg.d_model, dt),
    }
    g = group_size(cfg)
    n_groups = cfg.num_layers // g
    if cfg.arch_type == "ssm":
        params["blocks"] = [
            init_block(jax.random.fold_in(keys[1], i), cfg, _block_kind(cfg, i))
            for i in range(cfg.num_layers)]
    else:
        blocks = []
        for r in range(g):
            kind = _block_kind(cfg, r)
            def init_one(k):
                return init_block(k, cfg, kind)
            ks = jax.random.split(jax.random.fold_in(keys[1], r), n_groups)
            blocks.append(jax.vmap(init_one)(ks))
        params["blocks"] = tuple(blocks)
    if cfg.cross_attn_interval:
        ks = jax.random.split(keys[2], n_groups)
        params["cross_blocks"] = jax.vmap(
            lambda k: init_cross_block(k, cfg))(ks)
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "positions": L.truncated_normal(keys[4], (cfg.encoder_seq, cfg.d_model),
                                            0.02, dt),
            "blocks": jax.vmap(lambda k: init_block(k, cfg, "encoder"))(enc_keys),
            "final_norm": L.init_norm(cfg.norm_kind, cfg.d_model, dt),
        }
    return params


def model_axes(cfg: ModelConfig) -> PyTree:
    """Logical-axis tree matching init_model's structure (stacked dims get
    a leading "layers" axis)."""
    max_pos = cfg.max_target_positions if cfg.is_encdec else 0
    axes: Dict[str, PyTree] = {
        "embed": L.axes_embed(tie=cfg.tie_embeddings, max_positions=max_pos),
        "final_norm": L.axes_norm(cfg.norm_kind),
    }
    g = group_size(cfg)

    def stack(tree):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), tree,
                            is_leaf=lambda a: isinstance(a, tuple))

    if cfg.arch_type == "ssm":
        axes["blocks"] = [axes_block(cfg, _block_kind(cfg, i))
                          for i in range(cfg.num_layers)]
    else:
        axes["blocks"] = tuple(stack(axes_block(cfg, _block_kind(cfg, r)))
                               for r in range(g))
    if cfg.cross_attn_interval:
        axes["cross_blocks"] = stack(axes_cross_block(cfg))
    if cfg.is_encdec:
        axes["encoder"] = {
            "positions": (None, "embed"),
            "blocks": stack(axes_block(cfg, "encoder")),
            "final_norm": L.axes_norm(cfg.norm_kind),
        }
    return axes


# ---------------------------------------------------------------------------
# Forward (training) — scan over groups
# ---------------------------------------------------------------------------

def _encode_memory(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over precomputed conv-frontend frames [B, T, d]."""
    enc = params["encoder"]
    x = frames + enc["positions"][None, :frames.shape[1]]

    def body(x, bp):
        x, _ = apply_block(bp, x, cfg, "encoder", window=0, causal=False)
        return x, ()
    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, enc["blocks"])
    return L.apply_norm(enc["final_norm"], x, cfg.norm_kind)


def forward(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray, *,
            memory: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] -> (logits [B, S, V], aux loss scalar)."""
    from repro.sharding.constraints import constrain
    seq_ax = "seq" if cfg.seq_parallel_activations else None
    x = L.embed_tokens(params["embed"], tokens)
    x = constrain(x, "batch", seq_ax, None)
    if cfg.is_encdec:
        pos_table = params["embed"]["positions"]
        s = tokens.shape[1]
        x = x + jnp.take(pos_table, jnp.arange(s) % pos_table.shape[0], axis=0)[None]
        memory = _encode_memory(params, cfg, memory)
    g = group_size(cfg)
    ws = cfg.windows
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.arch_type == "ssm":
        for i, bp in enumerate(params["blocks"]):
            x, aux = apply_block(bp, x, cfg, _block_kind(cfg, i), window=ws[i])
            aux_total += aux
    else:
        has_cross = bool(cfg.cross_attn_interval)

        def body(carry, xs):
            x, aux_acc = carry
            x = constrain(x, "batch", seq_ax, None)
            blocks = xs[:g]
            cross = xs[g] if has_cross else None
            for r in range(g):
                kind = _block_kind(cfg, r)
                x, aux = apply_block(blocks[r], x, cfg, kind, window=ws[r],
                                     memory=memory)
                aux_acc = aux_acc + aux
            if has_cross:
                x = apply_cross_block(cross, x, memory, cfg)
            return (x, aux_acc), ()

        xs = tuple(params["blocks"])
        if has_cross:
            xs = xs + (params["cross_blocks"],)
        fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), xs)
        else:
            n_groups = cfg.num_layers // g
            for i in range(n_groups):
                (x, aux_total), _ = fn((x, aux_total),
                                       jax.tree.map(lambda t: t[i], xs))

    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind)
    logits = L.unembed(params["embed"], x, softcap=cfg.logit_softcap)
    return logits, aux_total
