"""Shared transformer building blocks (pure JAX, init/apply style).

Every ``init_*`` has a matching ``axes_*`` returning a pytree of *logical axis
name tuples* with the same structure — sharding/rules.py maps logical names to
mesh axes. The stacked-layer dimension is always logical axis "layers"
(never sharded).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Dict


def truncated_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype) -> PyTree:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def axes_norm(kind: str) -> PyTree:
    p = {"scale": ("embed",)}
    if kind == "layernorm":
        p["bias"] = ("embed",)
    return p


def apply_norm(p: PyTree, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Per-head qk-norm (qwen3): x [..., D], scale [D]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, H, S, D]; positions: [B, S] (or [S])."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (gated silu or plain gelu)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str, use_bias: bool, dtype) -> PyTree:
    ks = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, ff ** -0.5
    p = {"w_up": truncated_normal(ks[0], (d, ff), std_in, dtype),
         "w_down": truncated_normal(ks[1], (ff, d), std_out, dtype)}
    if act == "silu":
        p["w_gate"] = truncated_normal(ks[2], (d, ff), std_in, dtype)
    if use_bias:
        p["b_up"] = jnp.zeros((ff,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def axes_mlp(act: str, use_bias: bool) -> PyTree:
    p = {"w_up": ("embed", "ff"), "w_down": ("ff", "embed")}
    if act == "silu":
        p["w_gate"] = ("embed", "ff")
    if use_bias:
        p["b_up"] = ("ff",)
        p["b_down"] = ("embed",)
    return p


def apply_mlp(p: PyTree, x: jnp.ndarray, act: str) -> jnp.ndarray:
    up = x @ p["w_up"]
    if "b_up" in p:
        up = up + p["b_up"]
    if act == "silu":
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    out = up @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype, *, tie: bool,
               max_positions: int = 0) -> PyTree:
    ks = jax.random.split(key, 3)
    p = {"tokens": truncated_normal(ks[0], (vocab, d), d ** -0.5, dtype)}
    if not tie:
        p["unembed"] = truncated_normal(ks[1], (d, vocab), d ** -0.5, dtype)
    if max_positions:
        p["positions"] = truncated_normal(ks[2], (max_positions, d), 0.02, dtype)
    return p


def axes_embed(*, tie: bool, max_positions: int = 0) -> PyTree:
    p = {"tokens": ("vocab", "embed")}
    if not tie:
        p["unembed"] = ("embed", "vocab")
    if max_positions:
        p["positions"] = (None, "embed")
    return p


def embed_tokens(p: PyTree, tokens: jnp.ndarray, *, scale: bool = True) -> jnp.ndarray:
    x = p["tokens"][tokens]
    if scale:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def unembed(p: PyTree, x: jnp.ndarray, *, softcap: float = 0.0) -> jnp.ndarray:
    w = p.get("unembed")
    logits = x @ w if w is not None else x @ p["tokens"].T
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
