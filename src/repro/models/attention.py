"""GQA attention with RoPE, qk-norm, sliding windows, cross-attn, KV caches.

Train/prefill attention routes through the flash-attention Pallas kernel when
``impl`` is "pallas"/"pallas_interpret"; the jnp oracle otherwise (CPU + clean
dry-run HLO). Decode (single token vs cache) and cross-attention always use the
jnp path — both are O(S·D) matmuls with no online-softmax advantage.

Sliding-window layers keep a ring-buffer cache of ``window`` entries; global
layers keep the full-sequence cache. window == 0 means global.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Dict


def init_attention(key, d: int, num_heads: int, num_kv_heads: int, head_dim: int,
                   *, qk_norm: bool, use_bias: bool, dtype) -> PyTree:
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": L.truncated_normal(ks[0], (d, num_heads * head_dim), std, dtype),
        "wk": L.truncated_normal(ks[1], (d, num_kv_heads * head_dim), std, dtype),
        "wv": L.truncated_normal(ks[2], (d, num_kv_heads * head_dim), std, dtype),
        "wo": L.truncated_normal(ks[3], (num_heads * head_dim, d),
                                 (num_heads * head_dim) ** -0.5, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    if use_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def axes_attention(*, qk_norm: bool, use_bias: bool) -> PyTree:
    p = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    if use_bias:
        p["bq"] = ("heads",)
        p["bk"] = ("kv_heads",)
        p["bv"] = ("kv_heads",)
        p["bo"] = ("embed",)
    return p


def _project_qkv(p: PyTree, x: jnp.ndarray, xkv: jnp.ndarray, num_heads: int,
                 num_kv_heads: int, head_dim: int, qk_norm: bool):
    b, s = x.shape[0], x.shape[1]
    skv = xkv.shape[1]
    q = x @ p["wq"] + p.get("bq", 0.0)
    k = xkv @ p["wk"] + p.get("bk", 0.0)
    v = xkv @ p["wv"] + p.get("bv", 0.0)
    q = q.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, skv, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    if qk_norm:
        q = L.rms_head_norm(p["q_norm"], q)
        k = L.rms_head_norm(p["k_norm"], k)
    return q, k, v


def _sdpa_chunked(q, k, v, *, causal: bool, window: int,
                  chunk: int = 1024) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure JAX: lax.scan over query
    chunks so only [chunk × Skv] score slabs ever materialize. Same math as
    _sdpa (f32 accumulation); peak activation memory drops by Sq/chunk.

    This is the jnp twin of the Pallas kernel — used when the dry-run needs a
    CPU-lowerable module whose HLO does not carry S×S temporaries (§Perf).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    chunk = min(chunk, sq)
    if sq % chunk:
        chunk = sq  # ragged: fall back to one chunk
    n_chunks = sq // chunk
    qc = jnp.moveaxis(q.reshape(b, hq, n_chunks, chunk, dh), 2, 0)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    offset = skv - sq

    # Sliding-window layers only ever see keys in [qpos-window, qpos]: slice
    # the kv band per chunk instead of masking the full row — cuts score
    # traffic/FLOPs from O(S²) to O(S·(window+chunk)) (SWA-kernel analogue).
    import os as _os
    band = 0
    if (window and causal and window + chunk < skv
            and _os.environ.get("REPRO_DISABLE_WINDOW_BAND", "0") != "1"):
        band = chunk * ((window + chunk + chunk - 1) // chunk)  # multiple of chunk
        kf = jnp.pad(kf, ((0, 0), (0, 0), (band, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (band, 0), (0, 0)))

    def one_chunk(ci, q_blk):
        if band:
            start = ci * chunk + offset  # band-padded kv start for this chunk
            kb = jax.lax.dynamic_slice_in_dim(kf, start, band + chunk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vf, start, band + chunk, axis=2)
            logits = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                                kb) / (dh ** 0.5)
            qpos = jnp.arange(chunk)[:, None] + band
            kpos = jnp.arange(band + chunk)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            # exclude the zero-padding prepended before position 0
            mask &= (kpos + ci * chunk + offset) >= band
            logits = jnp.where(mask[None, None], logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1)
            return jnp.einsum("bhqk,bhkd->bhqd", probs, vb)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                            kf) / (dh ** 0.5)
        qpos = ci * chunk + jnp.arange(chunk)[:, None] + offset
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((chunk, skv), bool) if not causal else (kpos <= qpos)
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs, vf)

    outs = jax.lax.map(lambda args: one_chunk(*args),
                       (jnp.arange(n_chunks), qc))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, hq, sq, dh)
    return out.astype(q.dtype)


def _sdpa(q, k, v, *, causal: bool, window: int, q_offset: jnp.ndarray | int = 0,
          kv_valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """jnp reference attention. q: [B,H,Sq,D], k/v: [B,Hkv,Skv,D]."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (dh ** 0.5)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool) if not causal else (kpos <= qpos)
    if window:
        mask &= kpos > qpos - window
    if kv_valid_len is not None:
        mask = mask & (kpos < kv_valid_len)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def self_attention_kv(p: PyTree, x: jnp.ndarray, *, num_heads: int,
                      num_kv_heads: int, head_dim: int, window: int = 0,
                      rope_theta: float = 10000.0, qk_norm: bool = False,
                      positions: Optional[jnp.ndarray] = None,
                      impl: str = "reference", use_rope: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence causal self-attention returning the (roped) k/v for
    prefill cache construction. k, v: [B, Hkv, S, D]."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, x, num_heads, num_kv_heads, head_dim, qk_norm)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(s)
        q = L.apply_rope(q, pos, rope_theta)
        k = L.apply_rope(k, pos, rope_theta)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        out = kops.mha(q, k, v, causal=True, window=int(window) or None,
                       interpret=(impl == "pallas_interpret"))
    elif impl == "chunked":
        out = _sdpa_chunked(q, k, v, causal=True, window=int(window))
    else:
        out = _sdpa(q, k, v, causal=True, window=int(window))
    out = out.transpose(0, 2, 1, 3).reshape(b, s, num_heads * head_dim)
    return out @ p["wo"] + p.get("bo", 0.0), k, v


def self_attention(p: PyTree, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """Full-sequence causal self-attention (train / prefill)."""
    out, _, _ = self_attention_kv(p, x, **kw)
    return out


def cross_attention(p: PyTree, x: jnp.ndarray, memory: jnp.ndarray, *,
                    num_heads: int, num_kv_heads: int, head_dim: int,
                    qk_norm: bool = False) -> jnp.ndarray:
    """Non-causal attention over encoder/image memory (jnp path)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, memory, num_heads, num_kv_heads, head_dim, qk_norm)
    out = _sdpa(q, k, v, causal=False, window=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, num_heads * head_dim)
    return out @ p["wo"] + p.get("bo", 0.0)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, num_kv_heads: int, head_dim: int, *, seq_len: int,
                  window: int, dtype) -> PyTree:
    """Ring buffer of min(seq_len, window) entries for windowed layers."""
    size = min(seq_len, window) if window else seq_len
    shape = (batch, num_kv_heads, size, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def axes_kv_cache() -> PyTree:
    return {"k": ("batch", "kv_heads", None, None),
            "v": ("batch", "kv_heads", None, None)}


def decode_self_attention(p: PyTree, x: jnp.ndarray, cache: PyTree, pos: jnp.ndarray,
                          *, num_heads: int, num_kv_heads: int, head_dim: int,
                          window: int = 0, rope_theta: float = 10000.0,
                          qk_norm: bool = False, use_rope: bool = True
                          ) -> Tuple[jnp.ndarray, PyTree]:
    """One-token decode: x [B, 1, d], pos scalar int32 (current position).

    Returns (out [B, 1, d], updated cache). Windowed layers write the ring slot
    pos % window; global layers write slot pos.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, x, num_heads, num_kv_heads, head_dim, qk_norm)
    if use_rope:
        pvec = jnp.full((b, 1), pos, jnp.int32)
        q = L.apply_rope(q, pvec, rope_theta)
        k = L.apply_rope(k, pvec, rope_theta)
    size = cache["k"].shape[2]
    slot = (pos % size).astype(jnp.int32) if window else pos.astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)

    hq, hkv = num_heads, num_kv_heads
    kk, vv = ck, cv
    if hkv != hq:
        kk = jnp.repeat(kk, hq // hkv, axis=1)
        vv = jnp.repeat(vv, hq // hkv, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (head_dim ** 0.5)
    kidx = jnp.arange(size)[None, None, None, :]
    if window:
        valid = (kidx <= slot) | (pos >= size)  # ring: all slots valid once full
    else:
        valid = kidx <= pos
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32)).astype(x.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, num_heads * head_dim)
    return out @ p["wo"] + p.get("bo", 0.0), {"k": ck, "v": cv}
