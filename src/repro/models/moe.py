"""Mixture-of-experts FFN with top-k routing and capacity-based dispatch.

GShard/Switch-style dense dispatch with *token groups*: tokens are split into
groups of ``group_len`` along (batch, seq); dispatch/combine one-hots are built
per group, so the dispatch einsum costs O(T · E · C_g · d) with C_g ≈
cf·group_len·k/E — a 1-2% overhead over the expert FFN compute instead of the
O(T²) a single global group would cost. Shapes stay static, and GSPMD lowers
the grouped dispatch into an all-to-all when experts are sharded on the
``model`` axis (olmoe: 64 experts / 16). When experts don't divide the axis
(mixtral: 8), experts replicate and the expert hidden dim carries the axis
(sharding/rules.py).

Aux load-balancing loss follows Switch Transformer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Dict


def init_moe(key, d: int, ff: int, num_experts: int, act: str, dtype) -> PyTree:
    ks = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, ff ** -0.5
    p = {
        "router": L.truncated_normal(ks[0], (d, num_experts), std_in, jnp.float32),
        "w_up": L.truncated_normal(ks[1], (num_experts, d, ff), std_in, dtype),
        "w_down": L.truncated_normal(ks[2], (num_experts, ff, d), std_out, dtype),
    }
    if act == "silu":
        p["w_gate"] = L.truncated_normal(ks[3], (num_experts, d, ff), std_in, dtype)
    return p


def axes_moe(act: str) -> PyTree:
    p = {"router": ("embed", None),
         "w_up": ("experts", "embed", "expert_ff"),
         "w_down": ("experts", "expert_ff", "embed")}
    if act == "silu":
        p["w_gate"] = ("experts", "embed", "expert_ff")
    return p


def apply_moe(p: PyTree, x: jnp.ndarray, *, num_experts: int, top_k: int,
              capacity_factor: float, act: str, group_len: int = 512
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    g_len = min(group_len, s)
    assert s % g_len == 0, (s, g_len)
    g = b * (s // g_len)
    xt = x.reshape(g, g_len, d)

    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)  # [G,T,E]
    topw, topi = jax.lax.top_k(gates, top_k)                               # [G,T,k]
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * g_len * top_k / num_experts))
    onehot = jax.nn.one_hot(topi, num_experts, dtype=jnp.int32)            # [G,T,k,E]
    flat = onehot.reshape(g, g_len * top_k, num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                                  # [G,T*k,E]
    pos = jnp.sum(pos.reshape(g, g_len, top_k, num_experts) *
                  onehot, axis=-1)                                         # [G,T,k]
    keep = pos < capacity

    oh_e = jax.nn.one_hot(topi, num_experts, dtype=xt.dtype) * keep[..., None]
    oh_c = jax.nn.one_hot(pos, capacity, dtype=xt.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)                   # [G,T,E,C]
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                         topw.astype(xt.dtype))

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xt)                 # [G,E,C,d]
    up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    if act == "silu":
        up = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])) * up
    else:
        up = jax.nn.gelu(up)
    expert_out = jnp.einsum("gecf,efd->gecd", up, p["w_down"])
    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out).reshape(b, s, d)

    # Switch-style aux loss.
    density = jnp.mean(jax.nn.one_hot(topi[..., 0], num_experts),
                       axis=(0, 1))
    gate_mean = jnp.mean(gates, axis=(0, 1))
    aux = num_experts * jnp.sum(density * gate_mean)
    return out.astype(x.dtype), aux
