"""Model configuration covering all six assigned architecture families.

One frozen dataclass describes dense / MoE / SSM / hybrid / VLM / audio
(enc-dec) transformers. Per-layer heterogeneity (local vs global attention,
cross-attention insertion, mLSTM vs sLSTM) is encoded as data so homogeneous
stacks can be scanned:

- ``window_pattern``: per-layer sliding-window size, 0 = global attention.
  Carried into the scan as a traced per-layer array.
- ``cross_attn_interval``: VLM-style cross-attention block after every Nth
  self-attention layer (a separate stacked parameter group).
- ``block_pattern``: per-layer mixer kind for ssm/hybrid families.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # Attention details.
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    window_pattern: Tuple[int, ...] = ()  # per-layer window; () => all global
    rope_theta: float = 10000.0
    use_bias: bool = False
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"                # silu (gated) | gelu (ungated)
    logit_softcap: float = 0.0

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM / xLSTM / hybrid.
    ssm_state: int = 0               # mamba state size (hymba)
    ssm_expand: int = 2
    block_pattern: Tuple[str, ...] = ()  # per-layer: attn|parallel|mlstm|slstm

    # VLM.
    cross_attn_interval: int = 0     # every Nth layer gets a cross-attn block
    num_image_tokens: int = 0

    # Audio / encoder-decoder.
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)
    max_target_positions: int = 0    # learned positional table size (whisper)

    # Numerics / implementation.
    seq_parallel_activations: bool = False  # shard residual-stream seq dim on
                                            # 'model' at layer boundaries
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True
    scan_layers: bool = True
    attention_impl: str = "reference"   # reference | pallas | pallas_interpret
    source: str = ""                 # citation ([arXiv:...] / [hf:...])

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.window_pattern and len(self.window_pattern) != self.num_layers:
            raise ValueError("window_pattern must have num_layers entries")
        if self.block_pattern and len(self.block_pattern) != self.num_layers:
            raise ValueError("block_pattern must have num_layers entries")
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must divide evenly into kv groups")

    # -- derived properties ---------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def windows(self) -> Tuple[int, ...]:
        return self.window_pattern or (0,) * self.num_layers

    @property
    def max_window(self) -> int:
        """Largest finite window; 0 if any layer is global."""
        ws = self.windows
        return 0 if any(w == 0 for w in ws) else max(ws)

    @property
    def sub_quadratic(self) -> bool:
        """True if serve-state is o(seq²) compute AND o(seq) full-attn cache is
        avoided on every layer (long_500k eligibility)."""
        if self.arch_type in ("ssm",):
            return True
        if self.arch_type == "hybrid":
            return True  # attention heads are windowed (see hymba config)
        ws = self.windows
        if all(w > 0 for w in ws):
            return True  # every layer sliding-window (mixtral)
        # Mostly-local patterns (gemma3 5:1) are acceptable: decode is O(seq)
        # only on the sparse global layers.
        global_frac = sum(1 for w in ws if w == 0) / max(len(ws), 1)
        return global_frac <= 0.25

    def active_params(self) -> int:
        """Approximate active parameter count (MoE counts top-k experts)."""
        d, ff, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hq = self.num_heads * self.head_dim
        hkv = self.num_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if self.act == "silu":
            mlp_dense = 3 * d * ff
        else:
            mlp_dense = 2 * d * ff
        if self.is_moe:
            mlp = self.experts_per_token * mlp_dense + d * self.num_experts
        else:
            mlp = mlp_dense
        if self.arch_type == "ssm":
            attn, mlp = 0, 0
            for kind in (self.block_pattern or ("mlstm",) * l):
                di = self.ssm_expand * d
                if kind == "mlstm":
                    attn += 4 * d * di + di * d
                else:
                    attn += 8 * d * d
            body = attn
        else:
            body = l * (attn + mlp)
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.is_encdec:
            enc = self.encoder_layers * (4 * d * d + mlp_dense)
            body += l * (2 * d * d + hq * d)  # decoder cross-attn blocks
        if self.cross_attn_interval:
            n_cross = self.num_layers // self.cross_attn_interval
            body += n_cross * (d * hq + 2 * d * hkv + hq * d)
        return body + emb + enc

    def total_params(self) -> int:
        if not self.is_moe:
            return self.active_params()
        d, ff, l = self.d_model, self.d_ff, self.num_layers
        mlp_dense = 3 * d * ff if self.act == "silu" else 2 * d * ff
        per_layer_delta = (self.num_experts - self.experts_per_token) * mlp_dense
        return self.active_params() + l * per_layer_delta
