"""xLSTM blocks (mLSTM + sLSTM) — the [ssm] assigned arch (xlstm-125m).

mLSTM (matrix memory, exponential gating) runs CHUNKWISE on TPU: intra-chunk
a Q×Q decay-masked attention, inter-chunk a carried [B, H, Dh, Dh] matrix state
with accumulated decay — the recurrent and parallel forms of the xLSTM paper
fused at chunk granularity so prefill_32k never materializes S×S.

sLSTM (scalar memory, non-parallelizable recurrence) is a lax.scan over time,
kept for the layers the paper's 7:1 pattern assigns it.

Numerics: exponent arguments are clipped instead of carrying the running-max
stabilizer state; gates are computed in f32.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Dict
CHUNK = 128
_ICLIP = 8.0  # clip on the input-gate pre-activation (stabilization)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, num_heads: int, *, expand: int = 2, dtype=jnp.bfloat16) -> PyTree:
    di = expand * d
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "wq": L.truncated_normal(ks[0], (d, di), std, dtype),
        "wk": L.truncated_normal(ks[1], (d, di), std, dtype),
        "wv": L.truncated_normal(ks[2], (d, di), std, dtype),
        "w_igate": L.truncated_normal(ks[3], (d, num_heads), std, jnp.float32),
        "w_fgate": L.truncated_normal(ks[4], (d, num_heads), std, jnp.float32),
        "b_fgate": jnp.full((num_heads,), 3.0, jnp.float32),  # start remembering
        "b_igate": jnp.zeros((num_heads,), jnp.float32),
        "w_ogate": L.truncated_normal(ks[5], (d, di), std, dtype),
        "out_proj": L.truncated_normal(ks[6], (di, d), di ** -0.5, dtype),
    }


def axes_mlstm() -> PyTree:
    return {"wq": ("embed", "inner"), "wk": ("embed", "inner"),
            "wv": ("embed", "inner"), "w_igate": ("embed", None),
            "w_fgate": ("embed", None), "b_fgate": (None,), "b_igate": (None,),
            "w_ogate": ("embed", "inner"), "out_proj": ("inner", "embed")}


def _mlstm_gates(p: PyTree, x: jnp.ndarray, num_heads: int):
    """x: [..., d] -> q,k,v [..., H, Dh], log_f [..., H], log_i [..., H]."""
    di = p["wq"].shape[1]
    dh = di // num_heads
    def heads(t):
        return t.reshape(t.shape[:-1] + (num_heads, dh))
    q = heads(x @ p["wq"])
    k = heads(x @ p["wk"]) * (dh ** -0.5)
    v = heads(x @ p["wv"])
    logf = jax.nn.log_sigmoid((x.astype(jnp.float32) @ p["w_fgate"]) + p["b_fgate"])
    logi = jnp.clip((x.astype(jnp.float32) @ p["w_igate"]) + p["b_igate"],
                    -_ICLIP, _ICLIP)
    o = jax.nn.sigmoid(x @ p["w_ogate"])
    return q, k, v, logf, logi, o, dh


def apply_mlstm(p: PyTree, x: jnp.ndarray, num_heads: int, *,
                return_state: bool = False):
    """Chunkwise parallel mLSTM. x: [B, S, d]."""
    b, s, d = x.shape
    q, k, v, logf, logi, o, dh = _mlstm_gates(p, x, num_heads)
    qc = min(CHUNK, s)
    assert s % qc == 0
    nchunk = s // qc

    def chunked(t):  # [B, S, ...] -> [nchunk, B, qc, ...]
        return jnp.moveaxis(t.reshape(b, nchunk, qc, *t.shape[2:]), 1, 0)

    def chunk_step(carry, inp):
        cstate, nstate = carry                 # [B,H,Dh,Dh], [B,H,Dh]
        q_q, k_q, v_q, lf_q, li_q = inp        # [B,qc,H,...]
        lf_cum = jnp.cumsum(lf_q, axis=1)      # [B,qc,H]
        total = lf_cum[:, -1]                  # [B,H]

        qf = q_q.astype(jnp.float32)
        kf = k_q.astype(jnp.float32)
        vf = v_q.astype(jnp.float32)

        # Inter-chunk: query decays state from chunk start.
        w_inter = jnp.exp(jnp.clip(lf_cum, -60.0, 0.0))   # [B,qc,H]
        y_inter = jnp.einsum("bqhd,bhde,bqh->bqhe", qf, cstate, w_inter)
        n_inter = jnp.einsum("bqhd,bhd,bqh->bqh", qf, nstate, w_inter)

        # Intra-chunk: decay-masked attention, j <= i.
        # D_ij = exp(lf_cum_i - lf_cum_j + li_j)
        expo = (lf_cum[:, :, None] - lf_cum[:, None, :] + li_q[:, None, :])
        iidx = jnp.arange(qc)
        causal = iidx[:, None] >= iidx[None, :]
        expo = jnp.where(causal[None, :, :, None], jnp.clip(expo, -60.0, 30.0), -jnp.inf)
        dmat = jnp.exp(expo)                                # [B,qc,qc,H]
        scores = jnp.einsum("bqhd,bjhd->bqjh", qf, kf) * dmat
        y_intra = jnp.einsum("bqjh,bjhd->bqhd", scores, vf)
        n_intra = jnp.sum(scores, axis=2)                   # [B,qc,H]

        denom = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)[..., None]
        y = (y_inter + y_intra) / denom

        # State update: C' = exp(total) C + sum_j exp(total - lf_cum_j + li_j) k_j v_j^T
        wj = jnp.exp(jnp.clip(total[:, None] - lf_cum + li_q, -60.0, 30.0))  # [B,qc,H]
        c_new = (jnp.exp(jnp.clip(total, -60.0, 0.0))[..., None, None] * cstate
                 + jnp.einsum("bqhd,bqhe,bqh->bhde", kf, vf, wj))
        n_new = (jnp.exp(jnp.clip(total, -60.0, 0.0))[..., None] * nstate
                 + jnp.einsum("bqhd,bqh->bhd", kf, wj))
        return (c_new, n_new), y

    c0 = jnp.zeros((b, num_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, num_heads, dh), jnp.float32)
    xs = (chunked(q), chunked(k), chunked(v), chunked(logf), chunked(logi))
    (c_f, n_f), ys = jax.lax.scan(chunk_step, (c0, n0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, num_heads * dh)
    out = (o * y.astype(x.dtype)) @ p["out_proj"]
    if return_state:
        return out, {"c": c_f, "n": n_f}
    return out


def init_mlstm_state(batch: int, d: int, num_heads: int, *, expand: int = 2) -> PyTree:
    dh = expand * d // num_heads
    return {"c": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, num_heads, dh), jnp.float32)}


def decode_mlstm(p: PyTree, x: jnp.ndarray, cache: PyTree, num_heads: int
                 ) -> Tuple[jnp.ndarray, PyTree]:
    """One-token recurrent step. x: [B, 1, d]."""
    b = x.shape[0]
    q, k, v, logf, logi, o, dh = _mlstm_gates(p, x[:, 0], num_heads)
    f = jnp.exp(jnp.clip(logf, -60.0, 0.0))[..., None, None]        # [B,H,1,1]
    i = jnp.exp(logi)[..., None, None]
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    c = f * cache["c"] + i * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = f[..., 0] * cache["n"] + i[..., 0] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)[..., None]
    y = (num / den).reshape(b, num_heads * dh)
    out = (o * y.astype(x.dtype)) @ p["out_proj"]
    return out[:, None, :], {"c": c, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, num_heads: int, dtype=jnp.bfloat16) -> PyTree:
    ks = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "w_in": L.truncated_normal(ks[0], (d, 4 * d), std, jnp.float32),
        "r_in": L.truncated_normal(ks[1], (d, 4 * d), std, jnp.float32),
        "b_in": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0),
                                 jnp.zeros((d,))]).astype(jnp.float32),
        "out_proj": L.truncated_normal(ks[2], (d, d), std, dtype),
    }


def axes_slstm() -> PyTree:
    return {"w_in": ("embed", "inner"), "r_in": ("embed", "inner"),
            "b_in": ("inner",), "out_proj": ("embed", "embed")}


def _slstm_step(p: PyTree, carry, xt):
    """Stabilized sLSTM cell. xt: [B, d] f32."""
    c, n, h, m = carry
    z = xt @ p["w_in"] + h @ p["r_in"] + p["b_in"]
    zt, it, ft, ot = jnp.split(z, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(ft)
    log_i = jnp.clip(it, -_ICLIP, _ICLIP)
    m_new = jnp.maximum(log_f + m, log_i)
    i_gate = jnp.exp(log_i - m_new)
    f_gate = jnp.exp(log_f + m - m_new)
    c_new = f_gate * c + i_gate * jnp.tanh(zt)
    n_new = f_gate * n + i_gate
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def apply_slstm(p: PyTree, x: jnp.ndarray, num_heads: int, *,
                return_state: bool = False):
    b, s, d = x.shape
    del num_heads
    xf = x.astype(jnp.float32)
    zeros = jnp.zeros((b, d), jnp.float32)
    carry = (zeros, zeros, zeros, jnp.full((b, d), -1e9, jnp.float32))
    (c, n, hl, m), hs = jax.lax.scan(lambda c, xt: _slstm_step(p, c, xt),
                                     carry, jnp.moveaxis(xf, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = h @ p["out_proj"]
    if return_state:
        return out, {"c": c, "n": n, "h": hl, "m": m}
    return out


def init_slstm_state(batch: int, d: int) -> PyTree:
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((batch, d), -1e9, jnp.float32)}


def decode_slstm(p: PyTree, x: jnp.ndarray, cache: PyTree
                 ) -> Tuple[jnp.ndarray, PyTree]:
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), out = _slstm_step(p, carry, x[:, 0].astype(jnp.float32))
    return (out.astype(x.dtype) @ p["out_proj"])[:, None, :], \
        {"c": c, "n": n, "h": h, "m": m}
