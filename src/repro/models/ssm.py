"""Selective SSM (Mamba-style) mixer — used by the hybrid arch (hymba).

TPU adaptation: instead of the CUDA selective-scan kernel, the recurrence
    h_t = exp(A·dt_t) ⊙ h_{t-1} + dt_t·B_t·x_t,   y_t = C_t·h_t + D⊙x_t
runs CHUNKWISE: within a chunk of Q=128 steps an associative scan materializes
[B, Q, d_inner, n_state] in VMEM-sized pieces; across chunks a lax.scan carries
only the [B, d_inner, n_state] state. Peak memory is one chunk, sequential
length is S/Q — the memory-hierarchy-aware analogue of the paper's GPU kernel.

Decode is the plain single-step recurrence on the carried state.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Dict
CHUNK = 128


def init_mamba(key, d: int, *, expand: int, state: int, dtype) -> PyTree:
    di = expand * d
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    p = {
        "in_proj": L.truncated_normal(ks[0], (d, 2 * di), std, dtype),
        "w_bc": L.truncated_normal(ks[1], (di, 2 * state), di ** -0.5, dtype),
        "w_dt": L.truncated_normal(ks[2], (di, 1), di ** -0.5, dtype),
        "b_dt": jnp.full((1,), -4.0, dtype),  # softplus(-4) ~ small init dt
        "a_log": jnp.log(jnp.linspace(1.0, float(state), state, dtype=jnp.float32)
                         )[None, :].repeat(di, 0).astype(jnp.float32),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": L.truncated_normal(ks[3], (di, d), di ** -0.5, dtype),
    }
    return p


def axes_mamba() -> PyTree:
    return {"in_proj": ("embed", "inner"), "w_bc": ("inner", None),
            "w_dt": ("inner", None), "b_dt": (None,),
            "a_log": ("inner", None), "d_skip": ("inner",),
            "out_proj": ("inner", "embed")}


def _gates(p: PyTree, x: jnp.ndarray, state: int):
    """Shared projections. x: [..., d] -> (xt, z, dt, b, c)."""
    xz = x @ p["in_proj"]
    xt, z = jnp.split(xz, 2, axis=-1)                    # [..., di] each
    bc = xt @ p["w_bc"]
    b, c = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # [..., n]
    dt = jax.nn.softplus((xt @ p["w_dt"] + p["b_dt"]).astype(jnp.float32))  # [...,1]
    return xt, z, dt, b, c


def apply_mamba(p: PyTree, x: jnp.ndarray, *, state: int,
                return_state: bool = False):
    """Full-sequence chunkwise scan. x: [B, S, d] -> [B, S, d]
    (or (y, {"h": final_state}) when return_state)."""
    bsz, s, d = x.shape
    xt, z, dt, bmat, cmat = _gates(p, x, state)
    di = xt.shape[-1]
    a = -jnp.exp(p["a_log"])                              # [di, n]

    q = min(CHUNK, s)
    assert s % q == 0, (s, q)
    nchunk = s // q

    def reshape_chunks(t):
        return t.reshape(bsz, nchunk, q, *t.shape[2:])

    xt_c, dt_c = reshape_chunks(xt.astype(jnp.float32)), reshape_chunks(dt)
    b_c, c_c = reshape_chunks(bmat), reshape_chunks(cmat)

    def chunk_step(h, inputs):
        xt_q, dt_q, b_q, c_q = inputs                     # [B, q, ...]
        # Per-step decay & drive: [B, q, di, n]
        decay = jnp.exp(a[None, None] * dt_q[..., None])  # dt broadcast over n
        drive = (dt_q * xt_q)[..., None] * b_q[:, :, None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        h_all = acc_a * h[:, None] + acc_b                # [B, q, di, n]
        y = jnp.einsum("bqin,bqn->bqi", h_all, c_q)
        h_next = h_all[:, -1]
        return h_next, y

    h0 = jnp.zeros((bsz, di, state), jnp.float32)
    xs = (jnp.moveaxis(xt_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
          jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0))
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, di)
    y = y + xt.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x.dtype)) @ p["out_proj"]
    if return_state:
        return out, {"h": h_final}
    return out


def init_mamba_state(batch: int, d: int, *, expand: int, state: int) -> PyTree:
    return {"h": jnp.zeros((batch, expand * d, state), jnp.float32)}


def decode_mamba(p: PyTree, x: jnp.ndarray, cache: PyTree, *, state: int
                 ) -> Tuple[jnp.ndarray, PyTree]:
    """Single-step recurrence. x: [B, 1, d]."""
    xt, z, dt, bmat, cmat = _gates(p, x[:, 0], state)     # [B, ...]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(a[None] * dt[..., None])              # [B, di, n]
    drive = (dt * xt.astype(jnp.float32))[..., None] * bmat[:, None, :]
    h = decay * cache["h"] + drive
    y = jnp.einsum("bin,bn->bi", h, cmat)
    y = y + xt.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    return out[:, None, :], {"h": h}
