"""Model zoo for the 10 assigned architectures: shared layers, GQA/SWA
attention, MoE, mamba SSM, xLSTM, grouped-scan assembly, and serving paths."""
