"""SpreadFGL on JAX/TPU — edge-client collaborative federated graph learning
with adaptive neighbor generation (Zhong et al., 2024), plus the paper's
edge-layer aggregation lifted to multi-pod TPU training. See DESIGN.md."""
