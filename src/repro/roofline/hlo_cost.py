"""Loop-aware cost analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
scanned-layer model under-reports FLOPs/bytes/collectives by the trip count
(observed: 13-20× on the scanned train graphs). This module re-derives the
three roofline inputs from the HLO text with loop multipliers:

- computations are parsed into (symbol table, instructions);
- per-computation costs: dot FLOPs (2·|out|·|contract|), collective operand
  bytes (same conventions as analysis.collective_bytes), HBM byte traffic
  (operand+output bytes of top-level instructions, skipping free ops);
- a call-graph walk from ENTRY accumulates multipliers: ``body=`` edges of
  while ops scale by the ``known_trip_count`` backend_config, fusion
  ``calls=``/``to_apply`` edges count once per call site; fusion-body
  instructions contribute FLOPs but not HBM bytes (they live in registers/
  scratch — only the fusion's top-level operands/outputs touch HBM).

This intentionally approximates (elementwise FLOPs ignored — dots dominate;
convs unused in this framework). Validated against the unrolled decode graphs
where XLA's own numbers are trustworthy.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPNAME = re.compile(r"^\s*([a-z][a-z0-9\-]*)\(")
_ARGS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CALL_REFS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(text):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x] or []
        out.append((dtype, dims))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    refs: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # (callee, multiplier) — multiplier is trip count for while bodies


def parse_computations(text: str) -> Tuple[Dict[str, CompCost], str]:
    comps: Dict[str, CompCost] = {}
    entry = ""
    cur: CompCost = None
    symbols: Dict[str, Tuple[str, List[int]]] = {}
    cur_name = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        hm = _COMP_HEADER.match(line)
        if hm and line.endswith("{"):
            cur_name = hm.group(1)
            cur = comps.setdefault(cur_name, CompCost())
            symbols = {}
            if raw.startswith("ENTRY"):
                entry = cur_name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rest = im.group(1), im.group(2)
        out_shapes = []
        # output type(s): everything before the op name token
        opm = re.search(r"\)?\s([a-z][a-z0-9\-]*)\(", rest)
        head = rest[:opm.start()] if opm else rest
        op = opm.group(1) if opm else ""
        out_shapes = _shape_list(head)
        if out_shapes:
            symbols[name] = out_shapes[0]

        # call-graph refs + trip counts
        for rm in _CALL_REFS.finditer(rest):
            callee = rm.group(1)
            mult = 1.0
            if "body=" in rm.group(0):
                tm = _TRIP.search(rest)
                if tm:
                    mult = float(tm.group(1))
            cur.refs.append((callee, mult))

        if op in _FREE_OPS or not op:
            continue

        # operand shapes via symbol lookup
        operand_bytes = 0
        am = _ARGS.search(rest[opm.start():]) if opm else None
        arg_names = re.findall(r"%([\w.\-]+)", am.group(1)) if am else []
        for a in arg_names:
            if a in symbols:
                operand_bytes += _bytes_of([symbols[a]])

        out_bytes = _bytes_of(out_shapes)

        if op in ("fusion", "while", "conditional", "call", "custom-call",
                  "reduce", "map", "scatter", "select-and-scatter", "sort"):
            # traffic of the call boundary counts; inner computations are
            # accounted via refs (fusion bodies get zero hbm below)
            cur.hbm_bytes += out_bytes + operand_bytes
        elif op.rstrip("-startdone") in _COLLECTIVES or any(
                op.startswith(c) for c in _COLLECTIVES):
            base = next(c for c in _COLLECTIVES if op.startswith(c))
            if op.endswith("-done"):
                continue
            total = out_bytes
            if op.endswith("-start"):
                total //= 2
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
            n = int(gm.group(2)) if gm else 1
            if not gm:
                gm2 = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
                if gm2:
                    n = len(gm2.group(1).split(","))
            if base == "all-gather":
                total //= max(n, 1)
            elif base == "reduce-scatter":
                total *= max(n, 1)
            cur.coll[base] += total
            cur.hbm_bytes += out_bytes + operand_bytes
        elif op == "dot":
            cm = _CONTRACT.search(rest)
            contract = [int(x) for x in cm.group(1).split(",") if x] if cm else []
            lhs = symbols.get(arg_names[0]) if arg_names else None
            k = 1
            if lhs:
                for ci in contract:
                    if ci < len(lhs[1]):
                        k *= lhs[1][ci]
            out_elems = 1
            for _, dims in out_shapes[:1]:
                for d in dims:
                    out_elems *= d
            cur.flops += 2.0 * out_elems * k
            cur.hbm_bytes += out_bytes + operand_bytes
        else:
            cur.hbm_bytes += out_bytes + operand_bytes
    return comps, entry


def analyze_text(text: str) -> Dict[str, float]:
    """Loop-corrected totals: flops, hbm_bytes, per-kind collective bytes."""
    comps, entry = parse_computations(text)
    if not entry:
        return {"flops": 0.0, "hbm_bytes": 0.0,
                **{f"coll_{k}": 0.0 for k in _COLLECTIVES}}

    # fusion bodies: computations referenced via fusion instructions should
    # not contribute HBM bytes. We approximate: any computation whose name
    # contains "fused" or that is referenced only via calls= from fusion ops.
    # Simpler robust rule: computations reached via `calls=` contribute flops
    # and collectives but NOT hbm bytes (reduce/scatter bodies are tiny).
    multipliers: Dict[str, float] = {entry: 1.0}
    hbm_ok: Dict[str, bool] = {entry: True}
    order = [entry]
    seen: Set[str] = {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = comps.get(name)
        if comp is None:
            continue
        w = multipliers.get(name, 0.0)
        for callee, mult in comp.refs:
            multipliers[callee] = multipliers.get(callee, 0.0) + w * mult
            # while bodies keep HBM accounting; fusion/to_apply bodies don't
            is_loop_body = mult != 1.0 or callee.startswith(("region", "wide"))
            hbm_ok[callee] = hbm_ok.get(callee, False) or (
                hbm_ok.get(name, False) and is_loop_body)
            if callee not in seen:
                seen.add(callee)
                order.append(callee)

    totals = {"flops": 0.0, "hbm_bytes": 0.0,
              **{f"coll_{k}": 0.0 for k in _COLLECTIVES}}
    for name, comp in comps.items():
        w = multipliers.get(name, 0.0)
        if w <= 0:
            continue
        totals["flops"] += comp.flops * w
        if hbm_ok.get(name, False):
            totals["hbm_bytes"] += comp.hbm_bytes * w
        for kind, b in comp.coll.items():
            totals[f"coll_{kind}"] += b * w
    return totals
