"""Roofline model: TPU v5e constants, loop-aware HLO cost analysis, records."""
