"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies flops / bytes accessed. Collective bytes are parsed
out of the post-SPMD HLO text: operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async *-start variants
counted once).

NOTE on normalization: XLA's cost_analysis on the partitioned module reports
*per-device* numbers; the roofline divides by per-chip peaks only (no extra
chips factor), and ``MODEL_FLOPS`` (6·N·D per token, active params for MoE)
is divided by chips to compare like with like. Both raw values are kept in the
record so either convention can be recomputed.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,128,4096]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# `%x = <output-shapes> <kind>(<args>)` — XLA's text dialect does not inline
# operand types, so operand sizes are derived from the OUTPUT shape + the
# replica group size (all-gather output = operand × N, reduce-scatter the
# inverse, all-reduce/all-to-all/permute are size-preserving).
_KIND_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start|-done)?\s*\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind over the HLO module text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _KIND_RE.search(line)
        if not m or m.group("start") == "-done":
            continue
        kind = m.group("kind")
        out_shapes = m.group("out")
        total = 0
        for sm in _SHAPE_RE.finditer(out_shapes):
            dtype, dims = sm.group(1), sm.group(2)
            if dtype in _DTYPE_BYTES:
                total += _shape_bytes(dtype, dims)
        if m.group("start") == "-start":
            total //= 2  # async start outputs carry (operand, dest) pairs
        n = _group_size(line)
        if kind == "all-gather":
            total //= n
        elif kind == "reduce-scatter":
            total *= n
        out[kind] += total
    return out


@dataclasses.dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: Dict[str, int]   # per-device collective operand bytes
    model_flops: float           # analytic 6·N_active·D (global)
    memory_per_device: Optional[float] = None
    extra: Optional[Dict[str, Any]] = None

    @property
    def coll_total(self) -> int:
        return sum(self.coll_bytes.values())

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_total / hw.ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-chip comparison)."""
        if self.flops <= 0:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    def to_json(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "coll_bytes": self.coll_bytes,
            "coll_total": self.coll_total, "model_flops": self.model_flops,
            "memory_per_device": self.memory_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "extra": self.extra or {},
        }


def model_flops(cfg, shape) -> float:
    """Analytic 6·N·D (training) / 2·N·D (inference), N = active params."""
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(compiled, *, arch: str, shape, mesh_name: str, chips: int,
            cfg, extra: Optional[Dict[str, Any]] = None) -> RooflineRecord:
    """Primary numbers come from the loop-aware HLO analyzer (hlo_cost.py) —
    XLA's cost_analysis counts while bodies once and under-reports scanned
    models by the trip count. XLA's raw numbers are kept in ``extra`` and the
    larger of the two FLOPs estimates wins (each misses different ops: ours
    skips elementwise, XLA skips loop trips)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    from repro.roofline import hlo_cost
    corrected = hlo_cost.analyze_text(text)
    flops = max(xla_flops, corrected["flops"])
    byts = max(xla_bytes, corrected["hbm_bytes"])
    coll_corrected = {k: int(corrected[f"coll_{k}"]) for k in _COLLECTIVES}
    coll_raw = collective_bytes(text)
    coll = {k: max(coll_corrected[k], coll_raw[k]) for k in _COLLECTIVES}
    extra = dict(extra or {})
    extra.update(xla_flops=xla_flops, xla_bytes=xla_bytes,
                 corrected_flops=corrected["flops"],
                 corrected_bytes=corrected["hbm_bytes"],
                 coll_raw=coll_raw)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(getattr(ma, "temp_size_in_bytes", 0)
                        + getattr(ma, "argument_size_in_bytes", 0)
                        + getattr(ma, "output_size_in_bytes", 0)
                        - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineRecord(arch=arch, shape=shape.name, mesh=mesh_name,
                          chips=chips, flops=flops, hbm_bytes=byts,
                          coll_bytes=coll, model_flops=model_flops(cfg, shape),
                          memory_per_device=mem, extra=extra)
