"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per link
HBM_BYTES = 16 * 2 ** 30     # HBM capacity per chip

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
