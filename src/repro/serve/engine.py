"""Batched serving engine: prefill once, decode greedily/with sampling.

A thin, jit-compiled driver over models/decoding.py used by the serving
example and the decode benchmarks. Requests are padded to a common prompt
length (static shapes); generation is a lax.scan over decode steps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decoding
from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: PyTree
    max_len: int = 256

    def __post_init__(self):
        cfg = self.cfg

        @jax.jit
        def _prefill(params, tokens, memory):
            return decoding.prefill(params, cfg, tokens,
                                    max_len=self.max_len, memory=memory)

        @functools.partial(jax.jit, static_argnames=("steps", "temperature"))
        def _generate(params, cache, first_token, key, steps: int,
                      temperature: float):
            def body(carry, _):
                cache, token, key = carry
                logits, cache = decoding.decode_step(params, cfg, cache, token)
                if temperature > 0:
                    key, k2 = jax.random.split(key)
                    nxt = jax.random.categorical(k2, logits / temperature)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt = nxt[:, None].astype(jnp.int32)
                return (cache, nxt, key), nxt[:, 0]

            (cache, _, _), toks = jax.lax.scan(body, (cache, first_token, key),
                                               None, length=steps)
            return jnp.moveaxis(toks, 0, 1), cache  # [B, steps]

        self._prefill = _prefill
        self._generate = _generate

    def generate(self, prompts: np.ndarray, *, steps: int = 32,
                 temperature: float = 0.0, memory: Optional[np.ndarray] = None,
                 seed: int = 0) -> np.ndarray:
        """prompts: [B, S] int32 -> generated tokens [B, steps]."""
        assert prompts.shape[1] + steps <= self.max_len, "raise max_len"
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      jnp.asarray(memory) if memory is not None else None)
        first = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out, _ = self._generate(self.params, cache, first,
                                jax.random.key(seed), steps, temperature)
        return np.asarray(out)
