"""Batched serving engine over models/decoding.py (prefill + decode loop)."""
