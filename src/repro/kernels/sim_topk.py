"""Fused masked top-k similarity Pallas kernel for the imputation generator.

The graph imputation generator builds A̅ = H Hᵀ (Sec. III-C) over all nodes an
edge server covers — O(n²c) and the FGL-side hot spot — then keeps only the
top-k most similar *cross-subgraph* candidates per node. The jnp reference
path (imputation.similarity_topk) materializes a [block, n] gram slab in HBM,
masks it, and runs ``jax.lax.top_k`` over all n columns per row block.

This kernel fuses all three steps: each (row-block, col-block) grid step
computes one gram tile on the MXU, applies the same-client mask and the
candidate-target mask in registers, and folds the tile into a running
(values, indices) top-k carried in VMEM scratch across column tiles —
flash-attention style, so the [block_m, n] slab never round-trips through
HBM and the top-k reduction is streamed instead of re-run over all n columns.

The contraction dim c (num classes ≤ 15 in the paper's datasets) is far below
the 128-lane MXU width, so tiles are (block_m × c) @ (c × block_n): the cost
is dominated by streaming H, which the column grid tiles through VMEM.

Masked-out candidates carry -inf values; the running top-k seeds index slots
with -1, so rows with fewer than k valid candidates surface (-inf, -1) pairs
that ``imputation.similarity_topk`` maps to the (0.0, -1) convention. The
streaming merge (:func:`topk_merge`, shared with the candidate-sharded ring
driver in ``core/ring_topk.py``) breaks ties by smallest candidate index,
matching ``jax.lax.top_k``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def topk_merge(run_v: jnp.ndarray, run_i: jnp.ndarray, slab_v: jnp.ndarray,
               slab_i: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fold a candidate slab into a running (values, indices) top-k.

    The ONE streaming top-k merge shared by the fused Pallas kernel (column
    tiles arriving left to right), the jnp reference path, and the ring-
    sharded driver (``core/ring_topk.py``, candidate shards arriving in
    rotation order — NOT in index order). ``run_v``/``run_i`` are the
    ``[..., k]`` running top-k (-inf values / -1 indices on unfilled slots);
    ``slab_v``/``slab_i`` are a ``[..., m]`` slab of new candidates with
    -inf on masked entries and their (global) candidate indices.

    Selects the k largest of the k+m candidates with k unrolled argmax
    passes (k is small — the paper uses k ≤ 5 — and Mosaic has no sort/
    top_k primitive). Ties break by SMALLEST candidate index — jax.lax.
    top_k's tie-break on the full row — by value, not by position, so the
    result is independent of the order slabs are folded in: this is the
    invariant that lets per-shard partial top-ks over rotating candidate
    slabs finish bit-identical to the single-device reference.

    Exhausted rows (best == -inf) select among stale popped entries and
    unfilled -1 slots; the emitted index is forced to -1 either way, so
    rows with fewer than k valid candidates keep the (-inf, -1) convention.
    Live candidates always carry distinct indices (each candidate is folded
    exactly once), so exactly one entry pops per pass.
    """
    k = run_v.shape[-1]
    cand_v = jnp.concatenate([run_v, slab_v], axis=-1)     # [..., k+m]
    cand_i = jnp.concatenate([run_i, slab_i], axis=-1)
    new_v, new_i = [], []
    for _ in range(k):
        best = jnp.max(cand_v, axis=-1, keepdims=True)     # [..., 1]
        at_best = cand_v == best
        sel_i = jnp.min(jnp.where(at_best, cand_i, jnp.int32(2**30)),
                        axis=-1, keepdims=True)
        sel = at_best & (cand_i == sel_i)
        new_v.append(best)
        new_i.append(jnp.where(best > -jnp.inf, sel_i, -1))
        cand_v = jnp.where(sel, -jnp.inf, cand_v)
    return (jnp.concatenate(new_v, axis=-1),
            jnp.concatenate(new_i, axis=-1))


def _sim_topk_kernel(rows_ref, h_ref, row_cid_ref, col_cid_ref, col_mask_ref,
                     vals_ref, idx_ref, vals_scratch, idx_scratch,
                     *, k: int, block_n: int, col_offset: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        vals_scratch[...] = jnp.full_like(vals_scratch, -jnp.inf)
        idx_scratch[...] = jnp.full_like(idx_scratch, -1)

    rows = rows_ref[...].astype(jnp.float32)            # [bm, c]
    h = h_ref[...].astype(jnp.float32)                  # [bn, c]
    s = jax.lax.dot_general(rows, h, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bm, bn]

    # Fused masking: cross-subgraph only + valid candidate targets only.
    keep = (row_cid_ref[...] != col_cid_ref[...]) & (col_mask_ref[...] > 0)
    s = jnp.where(keep, s, -jnp.inf)
    # col_offset shifts local column positions to GLOBAL candidate indices
    # when the caller owns one shard of a larger candidate axis.
    col_idx = (col_offset + ki * block_n
               + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    new_v, new_i = topk_merge(vals_scratch[...], idx_scratch[...], s, col_idx)
    vals_scratch[...] = new_v
    idx_scratch[...] = new_i

    @pl.when(ki == nk - 1)
    def _finalize():
        vals_ref[...] = vals_scratch[...].astype(vals_ref.dtype)
        idx_ref[...] = idx_scratch[...]


def sim_topk(rows: jnp.ndarray, h: jnp.ndarray, row_cid: jnp.ndarray,
             col_cid: jnp.ndarray, col_mask: jnp.ndarray, k: int, *,
             block_m: int = 128, block_n: int = 512, col_offset: int = 0,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused masked top-k over the gram similarity rows @ hᵀ.

    rows: [b, c] query nodes; h: [n, c] candidate nodes; row_cid: [b, 1] and
    col_cid: [1, n] owning-client ids; col_mask: [1, n] valid-target mask
    (padding handled by ops.py). ``col_offset`` shifts emitted indices so a
    caller holding one shard of a larger candidate axis (``core/ring_topk``)
    gets GLOBAL candidate indices. Returns (vals [b, k] f32 with -inf on
    missing candidates, idx [b, k] int32 with -1 where never filled).
    """
    b, c = rows.shape
    n, c2 = h.shape
    assert c == c2
    assert b % block_m == 0 and n % block_n == 0, (b, n, block_m, block_n)
    assert 1 <= k <= n, (k, n)

    grid = (b // block_m, n // block_n)
    kernel = functools.partial(_sim_topk_kernel, k=k, block_n=block_n,
                               col_offset=col_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, c), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, c), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_m, k), jnp.float32),   # running top-k values
            pltpu.VMEM((block_m, k), jnp.int32),     # running top-k indices
        ],
        interpret=interpret,
    )(rows, h, row_cid, col_cid, col_mask)


def _sim_kernel(rows_ref, h_ref, o_ref):
    rows = rows_ref[...].astype(jnp.float32)    # [bm, c]
    h = h_ref[...].astype(jnp.float32)          # [bn, c]
    o_ref[...] = jax.lax.dot_general(
        rows, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def sim_block(rows: jnp.ndarray, h: jnp.ndarray, *, block_m: int = 128,
              block_n: int = 512, interpret: bool = False) -> jnp.ndarray:
    """rows: [b, c]; h: [n, c] -> [b, n] gram slab (padded by ops.py).

    The unfused building block (no masking, no top-k): kept as the
    micro-benchmark baseline the fused kernel is measured against and for
    callers that need the raw slab.
    """
    b, c = rows.shape
    n, c2 = h.shape
    assert c == c2
    assert b % block_m == 0 and n % block_n == 0, (b, n, block_m, block_n)

    grid = (b // block_m, n // block_n)
    return pl.pallas_call(
        _sim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, c), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), rows.dtype),
        interpret=interpret,
    )(rows, h)
