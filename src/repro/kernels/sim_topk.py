"""Gram-similarity row-block Pallas kernel for the imputation generator.

The graph imputation generator builds A̅ = H Hᵀ (Sec. III-C) over all nodes an
edge server covers — O(n²c) and the FGL-side hot spot. The framework never
materializes the full n×n gram: callers take row blocks and reduce them with
top-k immediately (imputation.similarity_topk). This kernel produces one
[block_rows × n] slab at a time.

The contraction dim c (num classes ≤ 15 in the paper's datasets) is far below
the 128-lane MXU width, so tiles are (block_m × c) @ (c × block_n): the cost is
dominated by streaming H, which the column grid tiles through VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(rows_ref, h_ref, o_ref):
    rows = rows_ref[...].astype(jnp.float32)    # [bm, c]
    h = h_ref[...].astype(jnp.float32)          # [bn, c]
    o_ref[...] = jax.lax.dot_general(
        rows, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def sim_block(rows: jnp.ndarray, h: jnp.ndarray, *, block_m: int = 128,
              block_n: int = 512, interpret: bool = False) -> jnp.ndarray:
    """rows: [b, c]; h: [n, c] -> [b, n] gram slab (padded by ops.py)."""
    b, c = rows.shape
    n, c2 = h.shape
    assert c == c2
    assert b % block_m == 0 and n % block_n == 0, (b, n, block_m, block_n)

    grid = (b // block_m, n // block_n)
    return pl.pallas_call(
        _sim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, c), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), rows.dtype),
        interpret=interpret,
    )(rows, h)
