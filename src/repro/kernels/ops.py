"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples, GQA head broadcast, and the
interpret-mode switch (CPU validation). Models call these; they never touch
pl.pallas_call directly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import sage_aggregate as _sage
from repro.kernels import sim_topk as _sim


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True,
        window: Optional[int] = None, block_q: int = 128, block_kv: int = 128,
        interpret: bool = False) -> jnp.ndarray:
    """Multi-head flash attention.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0 (GQA).
    Returns [B, Hq, Sq, D].
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    assert causal, "non-causal (cross) attention uses the jnp reference path"
    if hkv != hq:  # broadcast kv heads across their GQA group
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    block_q = min(block_q, max(8, sq))
    qp = _pad_to(q.reshape(b * hq, sq, d), 1, block_q)
    kp = _pad_to(k.reshape(b * hq, skv, d), 1, block_kv)
    vp = _pad_to(v.reshape(b * hq, skv, d), 1, block_kv)
    # Padding keys must never win the softmax: they sit at positions >= skv,
    # beyond every query position, so the causal mask already removes them
    # (ops are always causal here; window only tightens the mask).
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              block_q=block_q, block_kv=block_kv,
                              interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _sage_aggregate(adj, h, block_m, block_n, block_k, interpret):
    n, d = h.shape
    adj_p = _pad_to(_pad_to(adj, 0, block_m), 1, block_k)
    h_p = _pad_to(_pad_to(h, 0, block_k), 1, block_n)
    out = _sage.sage_aggregate(adj_p, h_p, block_m=block_m, block_n=block_n,
                               block_k=block_k, interpret=interpret)
    return out[:n, :d]


def _sage_aggregate_fwd(adj, h, block_m, block_n, block_k, interpret):
    return _sage_aggregate(adj, h, block_m, block_n, block_k, interpret), (adj, h)


def _sage_aggregate_bwd(block_m, block_n, block_k, interpret, res, g):
    # pallas_call has no autodiff rule: kernel forward, oracle backward. The
    # oracle computes the same clamped row-normalized mean, so its VJP is the
    # exact gradient of what the kernel produced (classifier training takes
    # grad through aggregation — see FGLTrainer._local_rounds).
    adj, h = res
    return jax.vjp(_ref.sage_aggregate, adj, h)[1](g)


_sage_aggregate.defvjp(_sage_aggregate_fwd, _sage_aggregate_bwd)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def sage_aggregate(adj: jnp.ndarray, h: jnp.ndarray, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """Row-normalized neighbor aggregation; accepts arbitrary [n,n]/[n,d]."""
    return _sage_aggregate(adj, h, block_m, block_n, block_k, interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def sim_block(rows: jnp.ndarray, h: jnp.ndarray, *, block_m: int = 128,
              block_n: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Gram slab rows @ hᵀ; accepts arbitrary [b,c]/[n,c]."""
    b, n = rows.shape[0], h.shape[0]
    block_m = min(block_m, max(8, b))
    block_n = min(block_n, max(8, n))
    rows_p = _pad_to(rows, 0, block_m)
    h_p = _pad_to(h, 0, block_n)
    out = _sim.sim_block(rows_p, h_p, block_m=block_m, block_n=block_n,
                         interpret=interpret)
    return out[:b, :n]


@functools.partial(jax.jit, static_argnames=("k", "block_m", "block_n",
                                             "col_offset", "interpret"))
def sim_topk(h: jnp.ndarray, client_ids: jnp.ndarray, target_mask: jnp.ndarray,
             k: int, *, block_m: int = 128, block_n: int = 512,
             col_offset: int = 0, interpret: bool = False):
    """Fused masked top-k similarity; accepts arbitrary [n,c]/[n]/[n].

    Per row of h: the k most similar rows of h whose ``client_ids`` differ
    and whose ``target_mask`` is set. ``col_offset`` shifts emitted indices
    to the global candidate axis when h is one shard of it. Returns (vals
    [n, k] f32 with -inf on missing candidates, idx [n, k] int32 with -1
    where never filled). Column padding gets mask 0, so padded slots can
    never be selected.
    """
    n = h.shape[0]
    block_m = min(block_m, max(8, n))
    block_n = min(block_n, max(8, n))
    rows_p = _pad_to(h, 0, block_m)
    h_p = _pad_to(h, 0, block_n)
    cid = client_ids.astype(jnp.int32)
    row_cid = _pad_to(cid[:, None], 0, block_m)
    col_cid = _pad_to(cid[None, :], 1, block_n)
    col_mask = _pad_to(target_mask.astype(jnp.float32)[None, :], 1, block_n)
    vals, idx = _sim.sim_topk(rows_p, h_p, row_cid, col_cid, col_mask, k,
                              block_m=block_m, block_n=block_n,
                              col_offset=col_offset, interpret=interpret)
    return vals[:n], idx[:n]
