"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors one kernel in this package; tests sweep shapes/dtypes and
``assert_allclose`` kernel(interpret=True) against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """Masked softmax attention, f32 accumulation.

    q: [B, H, Sq, D]; k, v: [B, H, Skv, D] (kv heads already broadcast to H).
    ``window``: sliding-window size (keys within [i-window+1, i] attend).
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sq, skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows -> 0
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def sage_aggregate(adj: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Row-normalized neighbor mean: (A @ H) / max(rowsum(A), 1).

    adj: [n, n] non-negative weights; h: [n, d]. f32 accumulation.
    """
    a = adj.astype(jnp.float32)
    agg = a @ h.astype(jnp.float32)
    deg = jnp.sum(a, axis=-1, keepdims=True)
    return (agg / jnp.maximum(deg, 1.0)).astype(h.dtype)


def sim_block(rows: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Gram-matrix row block of A̅ = H Hᵀ: rows @ hᵀ. rows: [b, c]; h: [n, c]."""
    return (rows.astype(jnp.float32) @ h.astype(jnp.float32).T).astype(rows.dtype)
