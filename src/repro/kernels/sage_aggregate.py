"""Fused GraphSAGE neighbor aggregation Pallas kernel (Eq. 3 hot spot).

Computes ``(A @ H) / max(rowsum(A), 1)`` in one pass: a tiled matmul over the
neighbor (contraction) dimension that accumulates both the aggregate and the
row degree in VMEM scratch, dividing on the last contraction step. Saves one
full read of A versus materializing the degree separately.

Grid: (row_blocks, col_blocks, k_blocks), k innermost. Tiles default to
128×128 (MXU-aligned); A tiles and H tiles stream HBM→VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sage_kernel(a_ref, h_ref, o_ref, acc_scratch, deg_scratch):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scratch[...] = jnp.zeros_like(acc_scratch)
        deg_scratch[...] = jnp.zeros_like(deg_scratch)

    a = a_ref[...].astype(jnp.float32)   # [bm, bk]
    h = h_ref[...].astype(jnp.float32)   # [bk, bn]
    acc_scratch[...] += jax.lax.dot_general(
        a, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    deg_scratch[...] += jnp.sum(a, axis=-1, keepdims=True)

    @pl.when(ki == nk - 1)
    def _finalize():
        deg = jnp.maximum(deg_scratch[...], 1.0)
        o_ref[...] = (acc_scratch[...] / deg).astype(o_ref.dtype)


def sage_aggregate(adj: jnp.ndarray, h: jnp.ndarray, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """adj: [n, n]; h: [n, d]; both padded to block multiples by ops.py."""
    n, n2 = adj.shape
    _, d = h.shape
    assert n2 == h.shape[0]
    assert n % block_m == 0 and n2 % block_k == 0 and d % block_n == 0

    grid = (n // block_m, d // block_n, n2 // block_k)
    return pl.pallas_call(
        _sage_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(adj, h)
