"""Pallas TPU kernels for the framework's compute hot spots.

- flash_attention: blocked online-softmax attention (train/prefill).
- sage_aggregate: fused normalized neighbor aggregation (GraphSAGE, Eq. 3).
- sim_topk.sim_block: gram-similarity slabs for the imputation generator.

``ops`` holds the jitted public wrappers; ``ref`` the pure-jnp oracles.
Import ``repro.kernels.ops`` lazily from model code so that merely importing
the models package never pulls in pallas.
"""
