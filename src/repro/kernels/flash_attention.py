"""Flash attention Pallas TPU kernel (train/prefill hot spot).

Online-softmax blocked attention (Dao et al. adapted to TPU): grid is
(batch*heads, q_blocks, kv_blocks) with the kv dimension iterated innermost so
the running max/denominator/accumulator live in VMEM scratch across kv steps.
Block shapes default to 128×128 — MXU-aligned (128 lanes) and small enough that
q, k, v, and the f32 accumulator tiles fit comfortably in ~16 MB VMEM:
   q(128×D) + k(128×D) + v(128×D) + acc(128×D) f32 ≈ 4·128·128·4 B = 256 KB.

Supports causal masking and sliding-window attention (Mixtral/Gemma-3 local
layers). GQA is handled in ops.py by broadcasting kv heads before the call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch, acc_scratch,
                  *, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_kv: int,
                  seq_kv: int, seq_q: int):
    """One (q_block, kv_block) step of online softmax."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0].astype(jnp.float32)            # [block_q, d]
    k = k_ref[0].astype(jnp.float32)            # [block_kv, d]
    v = v_ref[0].astype(jnp.float32)            # [block_kv, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # Positional mask. Query positions are aligned to the END of the kv axis
    # (prefill: seq_q == seq_kv; decode: seq_q << seq_kv attending to cache).
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (seq_kv - seq_q)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]                      # [block_q, 1]
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # [block_q, block_kv]
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scratch[...]
        # Fully-masked rows (l == 0) output zeros, matching the oracle.
        o_ref[0, :, :] = jnp.where(
            l > 0, acc_scratch[...] / jnp.maximum(l, 1e-30), 0.0
        ).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: [BH, Sq, D]; k, v: [BH, Skv, D] (heads pre-flattened/broadcast).

    Sq and Skv must be multiples of the block sizes (ops.py pads).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    scale = float(scale) if scale is not None else 1.0 / (d ** 0.5)
    grid = (bh, sq // block_q, skv // block_kv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_kv=skv, seq_q=sq)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
