"""Logical-axis -> mesh-axis sharding rules (MaxText-style, divisibility-aware).

Each parameter leaf carries a tuple of logical axis names (models/*.axes_*).
``logical_to_spec`` maps them to a PartitionSpec given the mesh, FALLING BACK
to replication when the dimension size does not divide the mesh axis — this is
what lets hymba's 25 heads or xlstm's 4 heads coexist with a 16-way model axis
(their ff/inner dims carry the axis instead).

Default rules (tensor parallel on "model", data parallel on ("pod","data")):
  vocab      -> model      (embedding/unembedding sharded over vocab)
  heads      -> model      (attention q heads)
  kv_heads   -> model      (falls back to replicated when kv < axis)
  ff         -> model      (dense MLP hidden)
  expert_ff  -> model      (MoE expert hidden; used when experts don't divide)
  experts    -> model      (expert parallelism when num_experts % axis == 0)
  inner      -> model      (mamba/mLSTM expanded inner dim)
  embed      -> data       (FSDP/ZeRO-3: weight d_model dim sharded over data;
                            all-gathered per layer under the scan)
  layers     -> None       (scan stack dim)
  batch      -> (pod, data)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

DEFAULT_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "expert_ff": "model",
    "experts": "model",
    "inner": "model",
    "embed": "data",   # FSDP: the d_model dim of weights shards over data
    "layers": None,
    "batch": "data",     # expanded to ("pod","data") when the mesh has pods
}


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return int(mesh.shape[name])


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def logical_to_spec(axes: Tuple, shape: Tuple[int, ...], mesh: Mesh,
                    rules: Optional[Dict[str, Optional[str]]] = None) -> P:
    """Map one leaf's logical axes to a PartitionSpec (divisibility fallback)."""
    rules = rules or DEFAULT_RULES
    entries = []
    used = set()
    for dim, name in zip(shape, axes):
        target = rules.get(name) if name is not None else None
        if name == "batch":
            target = batch_axes(mesh)
        if target is None:
            entries.append(None)
            continue
        if isinstance(target, str):
            target_t = (target,)
        else:
            target_t = tuple(target)
        if any(t not in mesh.shape for t in target_t):
            entries.append(None)
            continue
        if any(t in used for t in target_t):
            entries.append(None)  # an axis can shard only one dim
            continue
        if dim % _axis_size(mesh, target_t) != 0:
            entries.append(None)  # divisibility fallback -> replicate
            continue
        used.update(target_t)
        entries.append(target_t if len(target_t) > 1 else target_t[0])
    return P(*entries)


def spec_tree(axes_tree: PyTree, shape_tree: PyTree, mesh: Mesh,
              rules: Optional[Dict[str, Optional[str]]] = None) -> PyTree:
    """PartitionSpec pytree for a params tree.

    ``axes_tree`` leaves are tuples of logical names; ``shape_tree`` leaves are
    array-likes (or ShapeDtypeStructs) with .shape.
    """
    is_axes_leaf = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    return jax.tree.map(
        lambda a, s: logical_to_spec(a, s.shape, mesh, rules),
        axes_tree, shape_tree, is_leaf=is_axes_leaf)


def sharding_tree(axes_tree: PyTree, shape_tree: PyTree, mesh: Mesh,
                  rules: Optional[Dict[str, Optional[str]]] = None) -> PyTree:
    specs = spec_tree(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))
