"""Logical-axis sharding rules, dry-run spec builders, activation constraints."""
