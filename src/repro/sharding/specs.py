"""ShapeDtypeStruct + sharding builders for the multi-pod dry-run.

Everything here is allocation-free: parameter/optimizer/cache trees come from
``jax.eval_shape`` and get NamedShardings attached, so ``jit(...).lower()``
can compile every (arch × shape × mesh) combination on a CPU host with
``--xla_force_host_platform_device_count=512`` placeholder devices.

Cache sharding: KV caches are sharded over the *sequence* dim on the `model`
axis (kv_heads of the GQA archs are below 16 and would otherwise replicate a
multi-GB cache per chip); recurrent states shard their inner dim.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import InputShape
from repro.models import decoding, transformer
from repro.models.config import ModelConfig
from repro.optim.adam import Adam
from repro.sharding import rules
from repro.train import step as train_step_lib

PyTree = Any


def _attach(shapes: PyTree, shardings: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def param_specs(cfg: ModelConfig, mesh: Mesh) -> PyTree:
    shapes = jax.eval_shape(functools.partial(transformer.init_model, cfg=cfg),
                            jax.random.key(0))
    axes = transformer.model_axes(cfg)
    shardings = rules.sharding_tree(axes, shapes, mesh)
    return _attach(shapes, shardings)


def state_specs(cfg: ModelConfig, mesh: Mesh, optimizer: Adam) -> PyTree:
    params = param_specs(cfg, mesh)
    opt_shapes = jax.eval_shape(optimizer.init, params)
    axes = transformer.model_axes(cfg)
    mu_sh = rules.sharding_tree(axes, opt_shapes.mu, mesh)
    nu_sh = rules.sharding_tree(axes, opt_shapes.nu, mesh)
    opt = type(opt_shapes)(
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())),
        mu=_attach(opt_shapes.mu, mu_sh),
        nu=_attach(opt_shapes.nu, nu_sh),
    )
    return train_step_lib.TrainState(
        params=params, opt_state=opt,
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())))


def _batch_spec(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    axes = rules.batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % size == 0:
        return axes
    return None


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict[str, Any]:
    """Training/prefill batch: tokens (+ modality memory stub)."""
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_spec(mesh, b)
    out = {"tokens": jax.ShapeDtypeStruct(
        (b, s), jnp.int32, sharding=NamedSharding(mesh, P(ba, None)))}
    mem_shape = None
    if cfg.is_encdec:
        mem_shape = (b, cfg.encoder_seq, cfg.d_model)
    elif cfg.cross_attn_interval:
        mem_shape = (b, cfg.num_image_tokens, cfg.d_model)
    if mem_shape is not None:
        out["memory"] = jax.ShapeDtypeStruct(
            mem_shape, jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(ba, None, None)))
    return out


def _cache_entry_sharding(entry_shapes: Dict, cfg: ModelConfig, mesh: Mesh,
                          batch: int) -> Dict:
    ba = _batch_spec(mesh, batch)
    model_ok = "model" in mesh.shape
    out = {}
    for key2, s in entry_shapes.items():
        if key2 in ("k", "v"):
            seq = s.shape[2]
            seq_ax = "model" if (model_ok and seq % mesh.shape["model"] == 0) else None
            out[key2] = NamedSharding(mesh, P(ba, None, seq_ax, None))
        elif key2 == "h" and s.ndim == 3:   # mamba state [B, di, n]
            di = s.shape[1]
            ax = "model" if (model_ok and di % mesh.shape["model"] == 0) else None
            out[key2] = NamedSharding(mesh, P(ba, ax, None))
        elif key2 == "c" and s.ndim == 4:   # mlstm state [B, H, Dh, Dh]
            out[key2] = NamedSharding(mesh, P(ba, None, None, None))
        else:
            out[key2] = NamedSharding(mesh, P(ba) if s.ndim == 1 else
                                      P(*( [ba] + [None] * (s.ndim - 1))))
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> PyTree:
    b, s = shape.global_batch, shape.seq_len
    mem = None
    if cfg.is_encdec:
        mem = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    elif cfg.cross_attn_interval:
        mem = jax.ShapeDtypeStruct((b, cfg.num_image_tokens, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    shapes = jax.eval_shape(
        lambda m: decoding.init_cache(cfg, b, s, memory=m), mem)
    ba = _batch_spec(mesh, b)
    shardings = {"layers": [
        _cache_entry_sharding(entry, cfg, mesh, b)
        for entry in shapes["layers"]],
        "pos": NamedSharding(mesh, P())}
    if mem is not None:
        shardings["memory"] = NamedSharding(mesh, P(ba, None, None))
    return _attach(shapes, shardings)


def token_spec(shape: InputShape, mesh: Mesh) -> jax.ShapeDtypeStruct:
    b = shape.global_batch
    ba = _batch_spec(mesh, b)
    return jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                sharding=NamedSharding(mesh, P(ba, None)))
