"""Activation sharding constraints (mesh-aware, no-op outside a mesh).

GSPMD propagates weight shardings into activations; with FSDP-sharded weight
d_model dims ("embed" -> data) the propagation can pick batch-replicated
layouts (observed: 34 GB/device activation saves on mixtral train_4k).
``constrain`` pins the canonical activation layout at module boundaries:

  pattern entries: "batch" -> ("pod","data")  |  "seq" -> "model" (sequence
  parallelism) | "vocab"/"model" -> "model" | None -> replicated.

Outside jit-with-mesh (CPU unit tests) it is an exact no-op.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _abstract_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def constrain(x, *pattern: Optional[str]):
    """with_sharding_constraint(x, P(...)) resolved per the ambient mesh."""
    mesh = _abstract_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def _fits(axes, dim):
        total = 1
        for a in axes:
            total *= sizes[a]
        return total > 0 and dim % total == 0

    entries = []
    for dim, p in zip(x.shape, pattern):
        if p == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            if axes and _fits(axes, dim):
                entries.append(axes if len(axes) > 1 else axes[0])
            else:
                entries.append(None)
        elif p in ("seq", "vocab", "model", "heads", "ff"):
            if "model" in names and _fits(("model",), dim):
                entries.append("model")
            else:
                entries.append(None)
        else:
            entries.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        return x
