"""Pytree checkpointing to .npz (no orbax offline).

Flattens a pytree with '/'-joined key paths; restores into the same structure.
Handles dataclass/NamedTuple nodes via jax.tree flattening against a template,
including registered dataclasses like ``FGLState`` — the stacked [N]
edge-server generator state round-trips as ordinary leaves. Typed PRNG key
arrays are serialized via ``jax.random.key_data`` and re-wrapped on restore.

A restored ``FGLState`` is directly resumable: Python-scalar leaves in the
template (e.g. ``FGLState.round``) come back as Python scalars, so
``trainer.fit(state=io.restore(path, trainer.init(key, batch)))`` continues
Algorithm 1 at the checkpointed round with the imputation schedule intact —
and, for gossip compositions, the cross-server exchange phase too: both
schedules are pure functions of the absolute round (``round % K``), so no
extra state needs serializing (``tests/test_gossip.py`` pins the
mid-interval round-trip).
"""
from __future__ import annotations

import pathlib
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _is_key_array(leaf) -> bool:
    return isinstance(leaf, jax.Array) and jnp.issubdtype(leaf.dtype,
                                                          jax.dtypes.prng_key)


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(_path_str(p) for p in path) or "_root"
        if _is_key_array(leaf):
            leaf = jax.random.key_data(leaf)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save(path: str | pathlib.Path, tree: PyTree) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str | pathlib.Path, template: PyTree) -> PyTree:
    """Load arrays back into the structure of ``template``."""
    with np.load(pathlib.Path(path), allow_pickle=False) as data:
        flat = dict(data)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_entries, leaf in paths:
        key = "/".join(_path_str(p) for p in path_entries) or "_root"
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if _is_key_array(leaf):
            expect_shape = tuple(jax.random.key_data(leaf).shape)
            if tuple(arr.shape) != expect_shape:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {expect_shape}")
            leaves.append(jax.random.wrap_key_data(
                jnp.asarray(arr), impl=jax.random.key_impl(leaf)))
            continue
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        if isinstance(leaf, (int, float)) and not isinstance(leaf, bool):
            leaves.append(type(leaf)(arr))   # python scalar stays python scalar
        else:
            leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
