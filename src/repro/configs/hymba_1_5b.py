"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer; mostly
sliding-window attention with sparse global layers. [arXiv:2411.13676]"""
from repro.models.config import ModelConfig

ID = "hymba-1.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="hybrid", num_layers=32, d_model=1600, num_heads=25,
        num_kv_heads=5, d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_expand=2,
        # periodic 1 global : 15 local (the paper's 3 global layers adapted to
        # the scan-friendly period-16 pattern; noted in DESIGN.md)
        window_pattern=((0,) + (1024,) * 15) * 2,
        source="[arXiv:2411.13676]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="hybrid", num_layers=2, d_model=100,
        num_heads=5, num_kv_heads=1, d_ff=256, vocab_size=512,
        ssm_state=8, ssm_expand=2, window_pattern=(0, 64), dtype="float32",
        remat=False, source="[arXiv:2411.13676]",
    )
