"""gemma3-12b [dense]: 5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig

ID = "gemma3-12b"
_LOCAL = 1024  # sliding window of the local layers


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="dense", num_layers=48, d_model=3840, num_heads=16,
        num_kv_heads=8, d_ff=15360, vocab_size=262144,
        window_pattern=((_LOCAL,) * 5 + (0,)) * 8,   # 5 local : 1 global
        tie_embeddings=True, qk_norm=True, rope_theta=1e6,
        source="[hf:google/gemma-3-1b-pt]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        window_pattern=(64, 0), tie_embeddings=True, qk_norm=True,
        dtype="float32", remat=False, source="[hf:google/gemma-3-1b-pt]",
    )
