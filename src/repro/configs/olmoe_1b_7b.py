"""olmoe-1b-7b [moe]: 64 experts top-8. [arXiv:2409.02060]"""
from repro.models.config import ModelConfig

ID = "olmoe-1b-7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="moe", num_layers=16, d_model=2048, num_heads=16,
        num_kv_heads=16, d_ff=1024, vocab_size=50304,
        num_experts=64, experts_per_token=8, qk_norm=True,
        source="[arXiv:2409.02060]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=512,
        num_experts=4, experts_per_token=2, qk_norm=True, capacity_factor=2.0,
        dtype="float32", remat=False, source="[arXiv:2409.02060]",
    )
