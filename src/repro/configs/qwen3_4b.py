"""qwen3-4b [dense]: qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""
from repro.models.config import ModelConfig

ID = "qwen3-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="dense", num_layers=36, d_model=2560, num_heads=32,
        num_kv_heads=8, d_ff=9728, vocab_size=151936,
        qk_norm=True, tie_embeddings=True, rope_theta=1e6,
        source="[hf:Qwen/Qwen3-8B]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        qk_norm=True, tie_embeddings=True, dtype="float32", remat=False,
        source="[hf:Qwen/Qwen3-8B]",
    )
