"""llama-3.2-vision-11b [vlm]: gated cross-attn image layers every 5th layer.
Vision frontend (ViT + projector) is a STUB: input_specs supplies precomputed
projected patch embeddings [B, num_image_tokens, d_model]. [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig

ID = "llama-3.2-vision-11b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="vlm", num_layers=40, d_model=4096, num_heads=32,
        num_kv_heads=8, d_ff=14336, vocab_size=128256,
        cross_attn_interval=5, num_image_tokens=1024, rope_theta=5e5,
        source="[hf:meta-llama/Llama-3.2-11B-Vision]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="vlm", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        cross_attn_interval=2, num_image_tokens=16, dtype="float32",
        remat=False, source="[hf:meta-llama/Llama-3.2-11B-Vision]",
    )
