"""xlstm-125m [ssm]: mLSTM + sLSTM blocks (≈5:1). [arXiv:2405.04517]"""
from repro.models.config import ModelConfig

ID = "xlstm-125m"


def _pattern(n, slstm_at=(3, 9)):
    return tuple("slstm" if i in slstm_at else "mlstm" for i in range(n))


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="ssm", num_layers=12, d_model=768, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=50304,
        block_pattern=_pattern(12), ssm_expand=2, tie_embeddings=True,
        source="[arXiv:2405.04517]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="ssm", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=512,
        block_pattern=("mlstm", "slstm"), ssm_expand=2, tie_embeddings=True,
        dtype="float32", remat=False, source="[arXiv:2405.04517]",
    )
