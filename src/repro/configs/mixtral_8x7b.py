"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from repro.models.config import ModelConfig

ID = "mixtral-8x7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="moe", num_layers=32, d_model=4096, num_heads=32,
        num_kv_heads=8, d_ff=14336, vocab_size=32000,
        num_experts=8, experts_per_token=2,
        window_pattern=(4096,) * 32,        # SWA on every layer
        rope_theta=1e6, source="[arXiv:2401.04088]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        num_experts=4, experts_per_token=2, window_pattern=(64,) * 2,
        capacity_factor=2.0,  # drop-free for top-2-of-4: exact prefill/forward parity
        dtype="float32", remat=False, source="[arXiv:2401.04088]",
    )
