"""whisper-medium [audio]: enc-dec; conv/mel frontend is a STUB — input_specs
supplies precomputed frame embeddings [B, 1500, d_model]. [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

ID = "whisper-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="audio", num_layers=24, d_model=1024, num_heads=16,
        num_kv_heads=16, d_ff=4096, vocab_size=51865,
        encoder_layers=24, encoder_seq=1500, max_target_positions=448,
        norm_kind="layernorm", act="gelu", use_bias=True,
        source="[arXiv:2212.04356]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="audio", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
        encoder_layers=2, encoder_seq=32, max_target_positions=64,
        norm_kind="layernorm", act="gelu", use_bias=True, dtype="float32",
        remat=False, source="[arXiv:2212.04356]",
    )
