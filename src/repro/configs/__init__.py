"""Architecture registry: the 10 assigned architectures + input shapes.

``get_config(arch_id, variant)`` with variant "full" | "smoke".
``INPUT_SHAPES`` are the four assigned (seq_len, global_batch, kind) tuples.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

_MODULES = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "whisper-medium": "repro.configs.whisper_medium",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "llama3-405b": "repro.configs.llama3_405b",
    "xlstm-125m": "repro.configs.xlstm_125m",
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def get_config(arch_id: str, variant: str = "full", **overrides) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    cfg = getattr(mod, variant)()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (full-attn skips -> DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic and not cfg.is_encdec
    return True
