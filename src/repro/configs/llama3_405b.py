"""llama3-405b [dense]: GQA, 128k vocab-ish embedding table. [arXiv:2407.21783]"""
from repro.models.config import ModelConfig

ID = "llama3-405b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="dense", num_layers=126, d_model=16384,
        num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256,
        rope_theta=5e5, source="[arXiv:2407.21783]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="dense", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        dtype="float32", remat=False, source="[arXiv:2407.21783]",
    )
