"""command-r-plus-104b [dense]: GQA, no-bias, 256k vocab. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.config import ModelConfig

ID = "command-r-plus-104b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, arch_type="dense", num_layers=64, d_model=12288, num_heads=96,
        num_kv_heads=8, d_ff=33792, vocab_size=256000,
        norm_kind="layernorm", rope_theta=75e6, use_bias=False,
        source="[hf:CohereForAI/c4ai-command-r-v01]",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", arch_type="dense", num_layers=2, d_model=192,
        num_heads=6, num_kv_heads=2, d_ff=384, vocab_size=512,
        norm_kind="layernorm", dtype="float32", remat=False,
        source="[hf:CohereForAI/c4ai-command-r-v01]",
    )
