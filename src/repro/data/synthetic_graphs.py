"""Synthetic benchmark graphs (offline stand-ins for Cora/Citeseer/WikiCS/CoauthorCS).

The container has no network access, so the four benchmark datasets of the paper
are replaced with stochastic-block-model graphs whose (n, d, c, |E|) statistics
match Table I. Class-correlated features + homophilous edges preserve the
property the paper's claims rest on: GNN accuracy degrades when cross-subgraph
links are deleted and recovers when they are imputed.

``scale`` moves n/|E| proportionally in BOTH directions: most tests and
benchmarks use scale < 1 so CPU runs finish quickly, while ``scale > 1.0``
is the documented way to grow a Table-I dataset toward the 10k–1M-node
regime the scaling benchmarks sweep (``benchmarks/bench_sim_scaling.py``
reaches 1M nodes via a custom :class:`DatasetStats`). Node and edge counts
are monotone in ``scale``; the feature dim saturates at the dataset's real
``feature_dim`` once ``scale >= 0.25`` (growing n should not also inflate
every feature row). The scale-up path swaps the per-edge Python sampler for
a vectorized one — same SBM distribution, different rng stream — so the
generator stays deterministic in (stats, scale, seed) at every scale while
scale <= 1.0 graphs remain bit-identical to the historical sampler (both
regimes pinned in ``tests/test_synthetic_scale.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.types import Graph


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    name: str
    num_nodes: int
    num_edges: int
    feature_dim: int
    num_classes: int
    homophily: float  # fraction of edges within a class


# Table I of the paper.
DATASETS: Dict[str, DatasetStats] = {
    "cora": DatasetStats("cora", 2708, 5429, 1433, 7, 0.81),
    "citeseer": DatasetStats("citeseer", 3327, 4715, 3703, 6, 0.74),
    "wikics": DatasetStats("wikics", 11701, 215863, 300, 10, 0.65),
    "coauthor_cs": DatasetStats("coauthor_cs", 18333, 81894, 6805, 15, 0.80),
}


def make_sbm_graph(stats: DatasetStats, *, scale: float = 1.0, seed: int = 0,
                   feature_noise: float = 1.0, signal_ratio: float = 1.0) -> Graph:
    """Stochastic-block-model graph with class-centroid features.

    Nodes get a class label; edges are sampled so that ``homophily`` of them are
    intra-class; features are a class centroid plus isotropic noise, embedded in
    ``d`` dims. ``signal_ratio`` < 1 leaves a fraction of nodes with pure-noise
    features — those nodes are classifiable only through neighbor aggregation,
    which is what makes missing cross-subgraph links (and their imputation)
    matter, mirroring the role of multi-hop propagation in the paper.
    Deterministic given (stats, scale, seed).
    """
    rng = np.random.default_rng(seed)
    n = max(stats.num_classes * 8, int(round(stats.num_nodes * scale)))
    e = max(n, int(round(stats.num_edges * scale)))
    d = max(8, int(round(stats.feature_dim * min(1.0, scale * 4))))
    c = stats.num_classes

    y = rng.integers(0, c, size=n).astype(np.int32)
    # Class centroids, well separated but noisy.
    centroids = rng.normal(0.0, 1.0, size=(c, d)).astype(np.float32)
    x = centroids[y] + feature_noise * rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)
    if signal_ratio < 1.0:
        silent = rng.random(n) >= signal_ratio
        x[silent] = feature_noise * rng.normal(0.0, 1.0, size=(int(silent.sum()), d)).astype(np.float32)

    # Sample edges: homophilous fraction intra-class, rest uniform.
    if scale > 1.0:
        senders, receivers = _sample_edges_vectorized(rng, y, n, e, c,
                                                      stats.homophily)
    else:
        senders, receivers = _sample_edges_loop(rng, y, n, e, c,
                                                stats.homophily)
    keep = senders != receivers
    senders, receivers = senders[keep], receivers[keep]
    # Deduplicate undirected pairs.
    lo = np.minimum(senders, receivers)
    hi = np.maximum(senders, receivers)
    pairs = np.unique(np.stack([lo, hi], axis=1), axis=0)
    return Graph(x=x, senders=pairs[:, 0].astype(np.int32),
                 receivers=pairs[:, 1].astype(np.int32), y=y, num_classes=c)


def _sample_edges_loop(rng, y, n: int, e: int, c: int, homophily: float):
    """Per-edge Python sampler — the historical rng stream.

    Kept verbatim for ``scale <= 1.0``: every fixed-seed golden in the test
    suite was produced by this exact call sequence, so the small-graph
    regime must never change streams.
    """
    per_class = [np.where(y == k)[0] for k in range(c)]
    senders = np.empty(e, dtype=np.int32)
    receivers = np.empty(e, dtype=np.int32)
    intra = rng.random(e) < homophily
    for i in range(e):
        if intra[i]:
            k = int(y[rng.integers(0, n)])
            members = per_class[k]
            if len(members) < 2:
                senders[i], receivers[i] = rng.integers(0, n, size=2)
                continue
            u, v = rng.choice(members, size=2, replace=False)
        else:
            u, v = rng.integers(0, n, size=2)
        senders[i], receivers[i] = u, v
    return senders, receivers


def _sample_edges_vectorized(rng, y, n: int, e: int, c: int, homophily: float):
    """Batch sampler for the scale-up regime: O(e) numpy ops, no Python loop.

    Same SBM distribution as :func:`_sample_edges_loop` — an intra edge
    draws an anchor node uniformly (so class mass follows class size) and
    then two DISTINCT members of that class; an inter edge draws two
    uniform endpoints — but a different rng stream, which is why it only
    serves ``scale > 1.0`` (no historical goldens to preserve up there).
    """
    intra = rng.random(e) < homophily
    # Group nodes by class once: members of class k are
    # order[start[k] : start[k] + counts[k]].
    order = np.argsort(y, kind="stable").astype(np.int64)
    counts = np.bincount(y, minlength=c)
    start = np.concatenate([[0], np.cumsum(counts)[:-1]])

    k = y[rng.integers(0, n, size=e)].astype(np.int64)       # anchor's class
    m = counts[k]                                            # class sizes
    # Two distinct member slots via the shifted-draw trick: i2 is drawn from
    # the m-1 slots that are not i1.
    i1 = rng.integers(0, np.maximum(m, 1))
    i2 = rng.integers(0, np.maximum(m - 1, 1))
    i2 = i2 + (i2 >= i1)
    u_intra = order[start[k] + np.minimum(i1, m - 1)]
    v_intra = order[start[k] + np.minimum(i2, m - 1)]

    u_rand = rng.integers(0, n, size=e)
    v_rand = rng.integers(0, n, size=e)
    # Classes with < 2 members fall back to uniform, like the loop sampler.
    use_intra = intra & (m >= 2)
    senders = np.where(use_intra, u_intra, u_rand).astype(np.int32)
    receivers = np.where(use_intra, v_intra, v_rand).astype(np.int32)
    return senders, receivers


def load_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                 feature_noise: float = 1.0, signal_ratio: float = 1.0) -> Graph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return make_sbm_graph(DATASETS[name], scale=scale, seed=seed,
                          feature_noise=feature_noise, signal_ratio=signal_ratio)
