"""Synthetic benchmark graphs (offline stand-ins for Cora/Citeseer/WikiCS/CoauthorCS).

The container has no network access, so the four benchmark datasets of the paper
are replaced with stochastic-block-model graphs whose (n, d, c, |E|) statistics
match Table I. Class-correlated features + homophilous edges preserve the
property the paper's claims rest on: GNN accuracy degrades when cross-subgraph
links are deleted and recovers when they are imputed.

``scale`` shrinks n/d proportionally so CPU benchmarks finish quickly while
keeping c and the edge density; tests and benchmarks use scale < 1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.types import Graph


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    name: str
    num_nodes: int
    num_edges: int
    feature_dim: int
    num_classes: int
    homophily: float  # fraction of edges within a class


# Table I of the paper.
DATASETS: Dict[str, DatasetStats] = {
    "cora": DatasetStats("cora", 2708, 5429, 1433, 7, 0.81),
    "citeseer": DatasetStats("citeseer", 3327, 4715, 3703, 6, 0.74),
    "wikics": DatasetStats("wikics", 11701, 215863, 300, 10, 0.65),
    "coauthor_cs": DatasetStats("coauthor_cs", 18333, 81894, 6805, 15, 0.80),
}


def make_sbm_graph(stats: DatasetStats, *, scale: float = 1.0, seed: int = 0,
                   feature_noise: float = 1.0, signal_ratio: float = 1.0) -> Graph:
    """Stochastic-block-model graph with class-centroid features.

    Nodes get a class label; edges are sampled so that ``homophily`` of them are
    intra-class; features are a class centroid plus isotropic noise, embedded in
    ``d`` dims. ``signal_ratio`` < 1 leaves a fraction of nodes with pure-noise
    features — those nodes are classifiable only through neighbor aggregation,
    which is what makes missing cross-subgraph links (and their imputation)
    matter, mirroring the role of multi-hop propagation in the paper.
    Deterministic given (stats, scale, seed).
    """
    rng = np.random.default_rng(seed)
    n = max(stats.num_classes * 8, int(round(stats.num_nodes * scale)))
    e = max(n, int(round(stats.num_edges * scale)))
    d = max(8, int(round(stats.feature_dim * min(1.0, scale * 4))))
    c = stats.num_classes

    y = rng.integers(0, c, size=n).astype(np.int32)
    # Class centroids, well separated but noisy.
    centroids = rng.normal(0.0, 1.0, size=(c, d)).astype(np.float32)
    x = centroids[y] + feature_noise * rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)
    if signal_ratio < 1.0:
        silent = rng.random(n) >= signal_ratio
        x[silent] = feature_noise * rng.normal(0.0, 1.0, size=(int(silent.sum()), d)).astype(np.float32)

    # Sample edges: homophilous fraction intra-class, rest uniform.
    per_class = [np.where(y == k)[0] for k in range(c)]
    senders = np.empty(e, dtype=np.int32)
    receivers = np.empty(e, dtype=np.int32)
    intra = rng.random(e) < stats.homophily
    for i in range(e):
        if intra[i]:
            k = int(y[rng.integers(0, n)])
            members = per_class[k]
            if len(members) < 2:
                senders[i], receivers[i] = rng.integers(0, n, size=2)
                continue
            u, v = rng.choice(members, size=2, replace=False)
        else:
            u, v = rng.integers(0, n, size=2)
        senders[i], receivers[i] = u, v
    keep = senders != receivers
    senders, receivers = senders[keep], receivers[keep]
    # Deduplicate undirected pairs.
    lo = np.minimum(senders, receivers)
    hi = np.maximum(senders, receivers)
    pairs = np.unique(np.stack([lo, hi], axis=1), axis=0)
    return Graph(x=x, senders=pairs[:, 0].astype(np.int32),
                 receivers=pairs[:, 1].astype(np.int32), y=y, num_classes=c)


def load_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                 feature_noise: float = 1.0, signal_ratio: float = 1.0) -> Graph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return make_sbm_graph(DATASETS[name], scale=scale, seed=seed,
                          feature_noise=feature_noise, signal_ratio=signal_ratio)
