"""Synthetic LM data pipeline (offline container).

Deterministic Zipfian token stream with short-range structure (bigram copy
tendencies) so LM training loss visibly decreases; enough for e2e drivers and
convergence smoke tests. Also hosts the modality stubs: precomputed frame /
patch embeddings for the audio and vlm architectures (the one allowed stub).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


def token_batches(cfg: ModelConfig, *, batch: int, seq_len: int, seed: int = 0
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {"tokens": [B, S]} (+ "memory" for audio/vlm)."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab_size
    # Zipf-ish unigram with a copy process: p(repeat prev token) = 0.3.
    probs = 1.0 / np.arange(1, v + 1) ** 1.1
    probs /= probs.sum()
    while True:
        base = rng.choice(v, size=(batch, seq_len), p=probs)
        copy = rng.random((batch, seq_len)) < 0.3
        tokens = base.copy()
        tokens[:, 1:][copy[:, 1:]] = tokens[:, :-1][copy[:, 1:]]
        out: Dict[str, np.ndarray] = {"tokens": tokens.astype(np.int32)}
        mem = memory_stub(cfg, batch, rng=rng)
        if mem is not None:
            out["memory"] = mem
        yield out


def memory_stub(cfg: ModelConfig, batch: int, *, rng: Optional[np.random.Generator] = None
                ) -> Optional[np.ndarray]:
    """Precomputed modality embeddings (STUB frontends — see DESIGN.md).

    audio: conv/mel frame embeddings [B, encoder_seq, d_model];
    vlm: projected patch embeddings [B, num_image_tokens, d_model].
    """
    rng = rng or np.random.default_rng(0)
    if cfg.is_encdec:
        shape = (batch, cfg.encoder_seq, cfg.d_model)
    elif cfg.cross_attn_interval:
        shape = (batch, cfg.num_image_tokens, cfg.d_model)
    else:
        return None
    return rng.normal(0.0, 1.0, size=shape).astype(np.float32)
