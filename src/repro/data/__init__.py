"""Data substrate: synthetic graph datasets (Table I stand-ins) and the
LM token pipeline + modality stubs (DESIGN.md §8)."""
