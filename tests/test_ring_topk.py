"""Candidate-sharded ring top-k (core/ring_topk.py).

The contract under test, layer by layer:

- ``kernels.sim_topk.topk_merge`` is the ONE streaming merge shared by the
  Pallas kernel and the ring driver: it matches ``jax.lax.top_k`` including
  its smallest-index tie-break, and is invariant to the order candidate
  slabs are folded in — the invariant that makes rotation-order-independent
  sharding possible at all.
- ``ring_similarity_topk`` on a size-1 mesh is bit-identical to the
  ``"reference"`` path of ``imputation.similarity_topk``; real multi-device
  sharding (2/4/8 emulated devices, non-divisible n, fully-masked rows,
  k > valid candidates, tie-breaks) runs in a subprocess so the device count
  can be forced before jax initializes.
- The engine's sharded layout (``SpreadImputation(sim_mesh=...)``: vmap the
  generator half, one batched ring call outside) produces the same link
  proposals and fixed batch as the default in-vmap layout.
- Regression for the reference path: no [n, n]-shaped intermediate in its
  jaxpr (the same-client mask used to be materialized full-size).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imputation
from repro.core.ring_topk import (allgather_bytes, ring_rotation_bytes,
                                  ring_similarity_topk, ring_total_bytes,
                                  sim_topk_flops)
from repro.core.spreadfgl import make_spreadfgl
from repro.core.partition import partition_graph
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph
from repro.kernels.sim_topk import topk_merge


class _Mesh1:
    """Degenerate stand-in: size-1 mesh without touching device state."""
    size = 1


def _rand_case(rng, n, c, n_clients=3, mask_p=0.5):
    h = jnp.asarray(rng.standard_normal((n, c)), jnp.float32)
    cid = jnp.asarray(rng.integers(0, n_clients, n), jnp.int32)
    mask = jnp.asarray((rng.random(n) < mask_p), jnp.float32)
    return h, cid, mask


class TestTopkMerge:
    def test_matches_lax_topk_single_fold(self):
        rng = np.random.default_rng(0)
        vals = jnp.asarray(rng.standard_normal((6, 17)), jnp.float32)
        k = 5
        run_v = jnp.full((6, k), -jnp.inf, jnp.float32)
        run_i = jnp.full((6, k), -1, jnp.int32)
        idx = jnp.broadcast_to(jnp.arange(17, dtype=jnp.int32), vals.shape)
        got_v, got_i = topk_merge(run_v, run_i, vals, idx)
        exp_v, exp_i = jax.lax.top_k(vals, k)
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(exp_v))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(exp_i))

    def test_ties_resolve_to_smallest_index(self):
        # Three identical maxima at global indices 2, 9, 11: lax.top_k
        # returns them ascending; so must the merge.
        vals = jnp.zeros((1, 12), jnp.float32).at[0, jnp.array([2, 9, 11])].set(5.0)
        idx = jnp.arange(12, dtype=jnp.int32)[None, :]
        run_v = jnp.full((1, 3), -jnp.inf, jnp.float32)
        run_i = jnp.full((1, 3), -1, jnp.int32)
        _, got_i = topk_merge(run_v, run_i, vals, idx)
        np.testing.assert_array_equal(np.asarray(got_i), [[2, 9, 11]])

    @pytest.mark.parametrize("perm_seed", [0, 1, 2])
    def test_fold_order_invariance(self, perm_seed):
        """Folding slabs in ANY order gives the same result — with ties."""
        rng = np.random.default_rng(3)
        n, k, slabs = 48, 4, 4
        vals = rng.standard_normal((5, n)).astype(np.float32)
        vals[:, ::7] = 1.5                     # planted ties across slabs
        chunks = np.split(vals, slabs, axis=1)
        offsets = [i * (n // slabs) for i in range(slabs)]
        order = np.random.default_rng(perm_seed).permutation(slabs)

        def fold(sequence):
            rv = jnp.full((5, k), -jnp.inf, jnp.float32)
            ri = jnp.full((5, k), -1, jnp.int32)
            for s in sequence:
                idx = offsets[s] + jnp.arange(n // slabs, dtype=jnp.int32)
                rv, ri = topk_merge(rv, ri, jnp.asarray(chunks[s]),
                                    jnp.broadcast_to(idx, chunks[s].shape))
            return rv, ri

        v_seq, i_seq = fold(range(slabs))
        v_perm, i_perm = fold(order)
        np.testing.assert_array_equal(np.asarray(i_perm), np.asarray(i_seq))
        np.testing.assert_array_equal(np.asarray(v_perm), np.asarray(v_seq))
        exp_v, exp_i = jax.lax.top_k(jnp.asarray(vals), k)
        np.testing.assert_array_equal(np.asarray(i_seq), np.asarray(exp_i))
        np.testing.assert_array_equal(np.asarray(v_seq), np.asarray(exp_v))

    def test_underfilled_rows_keep_sentinels(self):
        vals = jnp.full((1, 6), -jnp.inf, jnp.float32).at[0, 4].set(1.0)
        idx = jnp.arange(6, dtype=jnp.int32)[None, :]
        rv = jnp.full((1, 3), -jnp.inf, jnp.float32)
        ri = jnp.full((1, 3), -1, jnp.int32)
        got_v, got_i = topk_merge(rv, ri, vals, idx)
        np.testing.assert_array_equal(np.asarray(got_i), [[4, -1, -1]])
        assert np.asarray(got_v)[0, 0] == 1.0
        assert np.isneginf(np.asarray(got_v)[0, 1:]).all()


class TestRingDriverSingleDevice:
    @pytest.mark.parametrize("n,k", [(64, 3), (37, 4), (10, 12)])
    def test_size1_matches_reference(self, n, k):
        rng = np.random.default_rng(n)
        h, cid, mask = _rand_case(rng, n, 5)
        kk = min(k, n)
        exp_s, exp_i = imputation.similarity_topk(
            h, jnp.ones(n), cid, kk, target_mask=mask)
        got_s, got_i = imputation.similarity_topk(
            h, jnp.ones(n), cid, kk, target_mask=mask, mesh=_Mesh1())
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(exp_i))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(exp_s))

    def test_batched_equals_per_element(self):
        rng = np.random.default_rng(7)
        hb = jnp.asarray(rng.standard_normal((3, 21, 4)), jnp.float32)
        cb = jnp.asarray(rng.integers(0, 3, (3, 21)), jnp.int32)
        mb = jnp.asarray(rng.integers(0, 2, (3, 21)), jnp.float32)
        vb, ib = ring_similarity_topk(hb, cb, mb, 4, mesh=_Mesh1())
        for b in range(3):
            v1, i1 = ring_similarity_topk(hb[b], cb[b], mb[b], 4, mesh=_Mesh1())
            np.testing.assert_array_equal(np.asarray(ib[b]), np.asarray(i1))
            np.testing.assert_array_equal(np.asarray(vb[b]), np.asarray(v1))

    def test_fully_masked_rows_emit_sentinels(self):
        rng = np.random.default_rng(9)
        h, cid, _ = _rand_case(rng, 30, 5)
        s, i = imputation.similarity_topk(h, jnp.ones(30), cid, 3,
                                          target_mask=jnp.zeros(30),
                                          mesh=_Mesh1())
        assert (np.asarray(i) == -1).all()
        assert (np.asarray(s) == 0.0).all()


class TestReferencePathMemory:
    def test_no_full_nn_intermediate_in_jaxpr(self):
        """The reference path must never build an [n, n] array — neither the
        gram matrix nor (the regression) the same-client mask."""
        n, c, block = 300, 5, 64
        h = jnp.zeros((n, c), jnp.float32)
        ones = jnp.ones(n, jnp.float32)
        cid = jnp.zeros(n, jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda h_, m_, c_: imputation.similarity_topk(
                h_, m_, c_, 4, kernel_impl="reference", block=block)
        )(h, ones, cid)

        offending = []

        def subjaxprs(v):
            if hasattr(v, "jaxpr"):                 # ClosedJaxpr
                yield v.jaxpr
            elif hasattr(v, "eqns"):                # bare Jaxpr
                yield v
            elif isinstance(v, (list, tuple)):
                for item in v:
                    yield from subjaxprs(item)

        def walk(jp):
            for eqn in jp.eqns:
                for var in eqn.outvars:
                    shape = getattr(getattr(var, "aval", None), "shape", ())
                    if len(shape) >= 2 and tuple(shape[-2:]) == (n, n):
                        offending.append((eqn.primitive.name, shape))
                for v in eqn.params.values():
                    for sub in subjaxprs(v):
                        walk(sub)

        walk(jaxpr.jaxpr)
        assert not offending, f"[n, n] intermediates found: {offending}"


class TestEngineShardedLayout:
    @pytest.fixture(scope="class")
    def small(self):
        g = make_sbm_graph(DATASETS["cora"], scale=0.10, seed=1,
                           feature_noise=3.0, signal_ratio=0.5)
        batch, _ = partition_graph(g, 4, aug_max=8, seed=0, label_ratio=0.3)
        cfg = FGLConfig(hidden_dim=16, local_rounds=2, imputation_interval=1,
                        top_k_links=3, aug_max=8)
        return batch, cfg

    def test_sim_mesh_layout_matches_default(self, small):
        """vmap-the-generator + one batched ring call == all-in-vmap, down
        to the fixed batch (size-1 mesh here; multi-device in subprocess)."""
        from jax.sharding import Mesh
        batch, cfg = small
        mesh = Mesh(np.array(jax.devices()[:1]), ("sim",))
        tr_ref = make_spreadfgl(cfg, batch, num_servers=2)
        tr_sh = make_spreadfgl(cfg, batch, num_servers=2, sim_mesh=mesh)
        state = tr_ref.init(jax.random.key(0), batch)
        (_, _, _, _, s_r, i_r, x_r), _ = tr_ref.imputation.server_outputs(
            tr_ref, state)
        (_, _, _, _, s_s, i_s, x_s), _ = tr_sh.imputation.server_outputs(
            tr_sh, state)
        np.testing.assert_array_equal(np.asarray(i_s), np.asarray(i_r))
        np.testing.assert_array_equal(np.asarray(s_s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(x_s), np.asarray(x_r))
        out_r = tr_ref._impute_fn(state)
        out_s = tr_sh._impute_fn(state)
        for name in ("x", "adj", "node_mask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out_s.batch, name)),
                np.asarray(getattr(out_r.batch, name)),
                err_msg=f"fixed batch .{name} diverged")


class TestTrafficModel:
    def test_rotation_bytes_and_flops(self):
        n, c, size = 1024, 32, 4
        per_rot = ring_rotation_bytes(n, c, size)
        assert per_rot == 256 * (32 * 4 + 8)
        assert ring_total_bytes(n, c, size) == 3 * per_rot
        assert ring_rotation_bytes(n, c, 1) == 0.0
        assert sim_topk_flops(10, n, c) == 2.0 * 10 * n * c
        # Ring total matches the ring all-gather volume for divisible n.
        assert ring_total_bytes(n, c, size) == allgather_bytes(n, c, size)


@pytest.mark.slow
def test_ring_parity_on_emulated_devices_subprocess():
    """Bit-identical parity on REAL multi-device meshes: 2/4/8 emulated
    devices, non-divisible n, fully-masked rows, k > valid candidates, and
    tie-break determinism across shard counts {1, 2, 4}."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=8")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import imputation

        rng = np.random.default_rng(0)
        cases = []
        for n in (64, 37, 11):                    # divisible / ragged / tiny
            h = jnp.asarray(rng.standard_normal((n, 6)), jnp.float32)
            cid = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
            mask = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
            cases.append((h, cid, mask, 4))
            cases.append((h, cid, jnp.zeros(n), 4))          # fully masked
            cases.append((h, cid, mask, min(n, 16)))         # k > valid cands
        # Tie case: duplicated feature rows => equal similarities.
        base = rng.standard_normal((6, 4)).astype(np.float32)
        h_tie = jnp.asarray(np.tile(base, (4, 1)))
        cid_tie = jnp.asarray(np.arange(24) % 2, jnp.int32)
        cases.append((h_tie, cid_tie, jnp.ones(24), 5))

        for h, cid, mask, k in cases:
            n = h.shape[0]
            exp_s, exp_i = imputation.similarity_topk(
                h, jnp.ones(n), cid, k, target_mask=mask)
            for nd in (1, 2, 4, 8):
                mesh = Mesh(np.array(jax.devices()[:nd]), ("sim",))
                got_s, got_i = imputation.similarity_topk(
                    h, jnp.ones(n), cid, k, target_mask=mask, mesh=mesh)
                np.testing.assert_array_equal(
                    np.asarray(got_i), np.asarray(exp_i),
                    err_msg=f"idx diverged: n={n} k={k} devices={nd}")
                np.testing.assert_array_equal(
                    np.asarray(got_s), np.asarray(exp_s),
                    err_msg=f"scores diverged: n={n} k={k} devices={nd}")
        print("RING-TOPK-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "RING-TOPK-OK" in out.stdout
