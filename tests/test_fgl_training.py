"""Integration tests: Algorithm 1 end-to-end + the paper's comparative claims
on reduced synthetic datasets (orderings, not absolute numbers)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import FedAvgFusion, FedSagePlus, LocalFGL
from repro.core.partition import partition_graph
from repro.core.spreadfgl import make_fedgl, make_spreadfgl
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph


@pytest.fixture(scope="module")
def setup():
    g = make_sbm_graph(DATASETS["cora"], scale=0.15, seed=1,
                       feature_noise=3.0, signal_ratio=0.5)
    batch, _ = partition_graph(g, 6, aug_max=12, seed=0, label_ratio=0.3)
    cfg = FGLConfig(hidden_dim=32, local_rounds=4, imputation_interval=2,
                    top_k_links=4, aug_max=12)
    return g, batch, cfg


def _fit(trainer, batch, rounds=8, seed=0):
    _, hist = trainer.fit(jax.random.key(seed), batch, rounds=rounds)
    return hist


class TestFedGL:
    def test_loss_decreases(self, setup):
        _, batch, cfg = setup
        hist = _fit(make_fedgl(cfg, batch), batch)
        assert hist["loss"][-1] < hist["loss"][0]

    def test_accuracy_above_chance(self, setup):
        g, batch, cfg = setup
        hist = _fit(make_fedgl(cfg, batch), batch)
        assert max(hist["acc"]) > 2.0 / g.num_classes

    def test_history_metrics_finite(self, setup):
        _, batch, cfg = setup
        hist = _fit(make_fedgl(cfg, batch), batch, rounds=4)
        for k in ("loss", "acc", "f1"):
            assert np.isfinite(hist[k]).all()


class TestSpreadFGL:
    def test_runs_with_three_servers(self, setup):
        _, batch, cfg = setup
        hist = _fit(make_spreadfgl(cfg, batch, num_servers=3), batch)
        assert hist["loss"][-1] < hist["loss"][0]

    def test_eq16_full_adjacency_equals_fedavg(self, setup):
        """With all-ones server adjacency, Eq. 16 == plain FedAvg."""
        _, batch, cfg = setup
        full_adj = np.ones((3, 3), dtype=np.float32)
        spread = make_spreadfgl(dataclasses.replace(cfg, trace_reg=0.0),
                                batch, num_servers=3, adjacency=full_adj)
        params = spread.init(jax.random.key(0), batch).params
        # perturb per-client so aggregation is nontrivial
        params = jax.tree.map(
            lambda p: p + jax.random.normal(jax.random.key(1), p.shape,
                                            p.dtype) * 0.01, params)
        agg = spread.aggregate(params)
        expect = jax.tree.map(lambda p: jnp.broadcast_to(p.mean(0, keepdims=True),
                                                         p.shape), params)
        for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_eq16_ring_differs_from_fedavg(self, setup):
        _, batch, cfg = setup
        # ring of 4 is NOT fully connected -> neighbor average != global mean
        g = make_sbm_graph(DATASETS["cora"], scale=0.12, seed=2)
        batch2, _ = partition_graph(g, 8, aug_max=8, seed=0)
        spread = make_spreadfgl(cfg, batch2, num_servers=4)
        params = spread.init(jax.random.key(0), batch2).params
        params = jax.tree.map(
            lambda p: p + jax.random.normal(jax.random.key(1), p.shape,
                                            p.dtype) * 0.1, params)
        agg = spread.aggregate(params)
        gmean = jax.tree.map(lambda p: jnp.broadcast_to(p.mean(0, keepdims=True),
                                                        p.shape), params)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(gmean)))
        assert diff > 1e-4

    def test_trace_regularizer_changes_loss(self, setup):
        _, batch, cfg = setup
        tr = make_spreadfgl(cfg, batch, num_servers=3)
        state = tr.init(jax.random.key(0), batch)
        l_with = float(tr._client_loss(state.params, state.batch))
        tr0 = make_spreadfgl(dataclasses.replace(cfg, trace_reg=0.0), batch,
                             num_servers=3)
        l_without = float(tr0._client_loss(state.params, state.batch))
        assert l_with > l_without  # Tr(W Wᵀ) > 0


class TestBaselines:
    def test_local_never_aggregates(self, setup):
        _, batch, cfg = setup
        tr = LocalFGL(cfg, batch)
        state = tr.init(jax.random.key(0), batch)
        perturbed = jax.tree.map(
            lambda p: p + jnp.arange(p.shape[0], dtype=p.dtype).reshape(
                (-1,) + (1,) * (p.ndim - 1)), state.params)
        agg = tr.aggregate(perturbed)
        for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(perturbed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fedsage_generates_local_neighbors(self, setup):
        _, batch, cfg = setup
        tr = FedSagePlus(cfg, batch)
        state = tr.init(jax.random.key(0), batch)
        state2 = tr._impute_fn(state)
        n_local = state2.batch.n_local_max
        assert float(jnp.sum(state2.batch.node_mask[:, n_local:])) > 0

    @pytest.mark.xfail(
        strict=False,
        reason="Table II's ordering does not reproduce at this reduced "
        "synthetic scale: with ~6 clients on a 0.15-scale SBM the per-client "
        "test split is small and class-skewed enough that a locally "
        "overfitted classifier wins (local max-acc ≈0.74 vs FedGL ≈0.68 at "
        "partition seeds 0/1; the ordering only flips at some seeds, e.g. "
        "partition seed 2). The benchmark suite tracks the orderings on the "
        "larger multi-dataset sweep instead.")
    def test_paper_ordering_local_worst(self, setup):
        """Table II claim (reduced): federated methods beat local training."""
        _, batch, cfg = setup
        local = max(_fit(LocalFGL(cfg, batch), batch)["acc"])
        fed = max(_fit(FedAvgFusion(cfg, batch), batch)["acc"])
        fedgl = max(_fit(make_fedgl(cfg, batch), batch)["acc"])
        assert fed > local
        assert fedgl > local


class TestAblations:
    """Fig. 7: each component can be disabled independently."""

    @pytest.mark.parametrize("kw", [
        dict(use_negative_sampling=False),
        dict(use_assessor=False),
        dict(use_negative_sampling=False, use_assessor=False),
    ])
    def test_ablated_variants_run(self, setup, kw):
        _, batch, cfg = setup
        hist = _fit(make_fedgl(cfg, batch, **kw), batch, rounds=4)
        assert np.isfinite(hist["loss"]).all()
