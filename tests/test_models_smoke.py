"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned architectures: instantiate the reduced variant,
run a forward pass + one train step (shape + finiteness asserts), and check
prefill/decode agree with the full forward — the serving-path invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.lm_data import memory_stub
from repro.models import decoding, transformer
from repro.optim.adam import Adam
from repro.train.step import init_state, lm_loss, make_train_step

ARCHS = list(configs.ARCH_IDS)


def _setup(arch, B=2, S=32):
    cfg = configs.get_config(arch, "smoke")
    tokens = np.asarray(jax.random.randint(jax.random.key(1), (B, S), 0,
                                           cfg.vocab_size))
    mem = memory_stub(cfg, B)
    batch = {"tokens": jnp.asarray(tokens)}
    if mem is not None:
        batch["memory"] = jnp.asarray(mem)
    return cfg, tokens, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_config_limits(arch):
    cfg = configs.get_config(arch, "smoke")
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.source  # citation present


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = configs.get_config(arch, "full")
    expected = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg, tokens, batch = _setup(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    logits, aux = jax.jit(
        lambda p, t, m: transformer.forward(p, cfg, t, memory=m))(
            params, batch["tokens"], batch.get("memory"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_finite_and_updates(arch):
    cfg, tokens, batch = _setup(arch)
    opt = Adam(lr=1e-3)
    state = init_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, state2.params)
    assert max(jax.tree.leaves(diffs)) > 0
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg, tokens, batch = _setup(arch)
    B, S = tokens.shape
    params = transformer.init_model(jax.random.key(0), cfg)
    full_logits, _ = jax.jit(
        lambda p, t, m: transformer.forward(p, cfg, t, memory=m))(
            params, batch["tokens"], batch.get("memory"))
    pf_logits, cache = jax.jit(
        lambda p, t, m: decoding.prefill(p, cfg, t, max_len=S + 4, memory=m))(
            params, jnp.asarray(tokens[:, :S - 1]), batch.get("memory"))
    np.testing.assert_allclose(np.asarray(pf_logits),
                               np.asarray(full_logits[:, S - 2]),
                               atol=2e-3, rtol=2e-3)
    dec_logits, cache2 = jax.jit(
        lambda p, c, t: decoding.decode_step(p, cfg, c, t))(
            params, cache, jnp.asarray(tokens[:, S - 1:S]))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, S - 1]),
                               atol=2e-3, rtol=2e-3)
    assert int(cache2["pos"]) == S


def test_ring_cache_prompt_longer_than_window():
    """Prefill with prompt > sliding window, then decode — ring buffer must
    hold exactly the last `window` keys in slot order."""
    cfg = configs.get_config("mixtral-8x7b", "smoke")  # window 64
    B, S = 1, 96
    params = transformer.init_model(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                cfg.vocab_size)
    full, _ = transformer.forward(params, cfg, tokens)
    pf, cache = decoding.prefill(params, cfg, tokens[:, :S], max_len=S + 8)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(full[:, S - 1]),
                               atol=2e-3, rtol=2e-3)
    dec, _ = decoding.decode_step(params, cfg, cache, tokens[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S]),
                               atol=2e-3, rtol=2e-3)


def test_loss_decreases_over_steps():
    """Short optimization on the smallest arch actually learns."""
    cfg = configs.get_config("xlstm-125m", "smoke")
    opt = Adam(lr=3e-3)
    state = init_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    from repro.data.lm_data import token_batches
    data = token_batches(cfg, batch=4, seq_len=64, seed=0)
    losses = []
    for _ in range(20):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_group_size_periodicity():
    assert transformer.group_size(configs.get_config("gemma3-12b", "full")) == 6
    assert transformer.group_size(configs.get_config("mixtral-8x7b", "full")) == 1
    assert transformer.group_size(configs.get_config("hymba-1.5b", "full")) == 16
    assert transformer.group_size(
        configs.get_config("llama-3.2-vision-11b", "full")) == 5


def test_sub_quadratic_classification():
    """long_500k applicability matches DESIGN.md §4."""
    runs = {a: configs.shape_applicable(configs.get_config(a, "full"),
                                        configs.INPUT_SHAPES["long_500k"])
            for a in ARCHS}
    assert runs == {
        "mixtral-8x7b": True, "gemma3-12b": True, "hymba-1.5b": True,
        "xlstm-125m": True,
        "command-r-plus-104b": False, "qwen3-4b": False,
        "llama-3.2-vision-11b": False, "whisper-medium": False,
        "olmoe-1b-7b": False, "llama3-405b": False,
    }
