"""Checkpointing, serving engine, data pipeline, gossip semantics."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import io as ckpt
from repro.data.lm_data import memory_stub, token_batches
from repro.models import transformer
from repro.optim.adam import Adam
from repro.serve.engine import ServeEngine
from repro.train.step import init_state


class TestCheckpoint:
    def test_roundtrip_params(self, tmp_path):
        cfg = configs.get_config("xlstm-125m", "smoke")
        params = transformer.init_model(jax.random.key(0), cfg)
        path = tmp_path / "ckpt.npz"
        ckpt.save(path, params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        restored = ckpt.restore(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_roundtrip_train_state(self, tmp_path):
        cfg = configs.get_config("qwen3-4b", "smoke")
        state = init_state(jax.random.key(0), cfg, Adam(lr=1e-3))
        path = tmp_path / "state.npz"
        ckpt.save(path, state)
        restored = ckpt.restore(path, jax.tree.map(jnp.zeros_like, state))
        np.testing.assert_array_equal(np.asarray(restored.step),
                                      np.asarray(state.step))

    def test_shape_mismatch_raises(self, tmp_path):
        ckpt.save(tmp_path / "x.npz", {"a": jnp.ones((3,))})
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path / "x.npz", {"a": jnp.ones((4,))})

    def test_missing_leaf_raises(self, tmp_path):
        ckpt.save(tmp_path / "x.npz", {"a": jnp.ones((3,))})
        with pytest.raises(KeyError):
            ckpt.restore(tmp_path / "x.npz", {"b": jnp.ones((3,))})


class TestServeEngine:
    @pytest.mark.parametrize("arch", ["qwen3-4b", "xlstm-125m", "hymba-1.5b"])
    def test_generate_shapes(self, arch):
        cfg = configs.get_config(arch, "smoke")
        params = transformer.init_model(jax.random.key(0), cfg)
        eng = ServeEngine(cfg, params, max_len=64)
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(2, 16)).astype(np.int32)
        out = eng.generate(prompts, steps=8)
        assert out.shape == (2, 8)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()

    def test_greedy_deterministic(self):
        cfg = configs.get_config("qwen3-4b", "smoke")
        params = transformer.init_model(jax.random.key(0), cfg)
        eng = ServeEngine(cfg, params, max_len=48)
        prompts = np.random.default_rng(1).integers(
            0, cfg.vocab_size, size=(1, 8)).astype(np.int32)
        a = eng.generate(prompts, steps=6)
        b = eng.generate(prompts, steps=6)
        np.testing.assert_array_equal(a, b)

    def test_memory_archs_serve(self):
        cfg = configs.get_config("whisper-medium", "smoke")
        params = transformer.init_model(jax.random.key(0), cfg)
        eng = ServeEngine(cfg, params, max_len=48)
        prompts = np.zeros((2, 4), np.int32)
        mem = memory_stub(cfg, 2)
        out = eng.generate(prompts, steps=4, memory=mem)
        assert out.shape == (2, 4)


class TestData:
    def test_token_batches_shapes_and_range(self):
        cfg = configs.get_config("qwen3-4b", "smoke")
        it = token_batches(cfg, batch=3, seq_len=17)
        b = next(it)
        assert b["tokens"].shape == (3, 17)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size

    def test_memory_stub_only_for_modal_archs(self):
        assert memory_stub(configs.get_config("qwen3-4b", "smoke"), 2) is None
        m = memory_stub(configs.get_config("whisper-medium", "smoke"), 2)
        assert m.shape == (2, 32, 128)
        v = memory_stub(configs.get_config("llama-3.2-vision-11b", "smoke"), 2)
        assert v.shape == (2, 16, 128)


@pytest.mark.slow
def test_gossip_preserves_mean_subprocess():
    """ring_gossip is doubly-stochastic: the pod-average of parameters is
    invariant (the SpreadFGL convergence argument relies on this)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import gossip
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.arange(8.0 * 5).reshape(8, 5)

        def f(blk):
            out = gossip.ring_gossip({"w": blk[0]}, "pod")
            return out["w"][None]

        y = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"),),
                              out_specs=P("pod"), check_rep=False))(x)
        np.testing.assert_allclose(np.asarray(y.mean(0)), np.asarray(x.mean(0)),
                                   rtol=1e-6)
        # each row is the average of itself and its ring neighbors
        for i in range(8):
            expect = (x[i] + x[(i-1) % 8] + x[(i+1) % 8]) / 3.0
            np.testing.assert_allclose(np.asarray(y[i]), np.asarray(expect),
                                       rtol=1e-6)
        print("GOSSIP-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "GOSSIP-OK" in out.stdout, out.stderr[-2000:]
