"""FedBuff-style async aggregation (``strategies.AsyncAggregator``).

The determinism contract under test, the same one ``participation_mask``
and the gossip phase already honor:

- the delay/dropout draws come from a key stream f(cfg.seed, round) under a
  dedicated salt — independent of the training key AND the participation
  stream — so enabling async aggregation never perturbs other randomness;
- the buffer is a static [M] occupancy and the flush weights reach the
  jitted aggregation as a traced [M] vector, flush/skip being the only
  static split;
- the whole delay/buffer/staleness schedule is a pure function of the
  absolute round, so save/resume mid-buffer replays it exactly.

Correctness anchor: B = M with zero delays and no dropouts reproduces the
synchronous FedAvg compositions BIT-identically (every weight is exactly
1.0 and the reduction order matches), pinned here both on the raw
aggregator and on full fixed-seed training histories against the FedGL
golden.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io
from repro.core import registry
from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.spreadfgl import make_spreadfgl_async

# `small` comes from the session-scoped fixture in tests/conftest.py.

M = 4  # clients in the `small` fixture

# The pinned fixed-seed FedGL history of tests/test_strategy_api.py
# (fit(key(0), rounds=4) on the `small` fixture). The async anchor must
# reproduce the SAME run bit-for-bit, so it must also match this golden.
GOLDEN_FEDGL = {
    "loss": [1.5929425954818726, 0.27329501509666443,
             0.07562695443630219, 0.03868856653571129],
    "acc": [0.16363635659217834, 0.23636363446712494,
            0.34545454382896423, 0.34545454382896423],
    "f1": [0.09297052770853043, 0.18033909797668457,
           0.2997002899646759, 0.3178369402885437],
}


def _sync_cfg(cfg, **kw):
    """The small config with async fields set."""
    return dataclasses.replace(cfg, **kw)


def _schedule_oracle(seed, m, buffer_size, delay_dist, max_delay,
                     dropout_rate, rounds):
    """An independent pure-python replay of the client/buffer state machine.

    Deliberately structured differently from ``strategies._async_schedule``
    (per-client dict state instead of vectorized arrays) so the two can only
    agree if the semantics — send/arrive/freshest-wins/flush — agree.
    """
    in_flight = {}   # client -> arrival round
    buffered = {}    # client -> report round
    out = []
    for t in range(rounds):
        delays, drops = S.async_delay_stream(
            seed, t, m, delay_dist=delay_dist, max_delay=max_delay,
            dropout_rate=dropout_rate)
        for i in range(m):
            if i not in in_flight and not drops[i]:
                in_flight[i] = t + int(delays[i])
        for i in [i for i, arr in in_flight.items() if arr == t]:
            buffered[i] = t          # fresher report replaces a staler one
            del in_flight[i]
        if len(buffered) >= buffer_size:
            w = np.zeros(m, np.float32)
            for i, rep in buffered.items():
                w[i] = 1.0 / np.sqrt(np.float32(1.0) + np.float32(t - rep))
            buffered = {}
            out.append((True, w))
        else:
            out.append((False, None))
    return out


class TestDelayStream:
    def test_zero_dist_has_no_delays(self):
        delays, drops = S.async_delay_stream(0, 3, 8)
        np.testing.assert_array_equal(delays, np.zeros(8, np.int32))
        assert not drops.any()

    @pytest.mark.parametrize("dist", S.ASYNC_DELAY_DISTS)
    def test_same_seed_round_reproduces(self, dist):
        a = S.async_delay_stream(7, 5, 10, delay_dist=dist, dropout_rate=0.3)
        b = S.async_delay_stream(7, 5, 10, delay_dist=dist, dropout_rate=0.3)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_draws_vary_across_rounds(self):
        draws = [S.async_delay_stream(0, t, 16, delay_dist="uniform",
                                      dropout_rate=0.5) for t in range(6)]
        assert any(np.any(draws[0][0] != d[0]) for d in draws[1:])
        assert any(np.any(draws[0][1] != d[1]) for d in draws[1:])

    @pytest.mark.parametrize("dist", ("uniform", "geometric"))
    def test_delays_bounded_by_max_delay(self, dist):
        for t in range(10):
            delays, _ = S.async_delay_stream(1, t, 32, delay_dist=dist,
                                             max_delay=3)
            assert delays.min() >= 0 and delays.max() <= 3

    def test_geometric_mass_at_zero(self):
        """p=1/2 geometric: about half of all draws arrive the same round."""
        all_delays = np.concatenate([
            S.async_delay_stream(0, t, 64, delay_dist="geometric")[0]
            for t in range(16)])
        frac0 = (all_delays == 0).mean()
        assert 0.35 < frac0 < 0.65, frac0

    def test_dropout_zero_never_drops(self):
        for t in range(8):
            _, drops = S.async_delay_stream(2, t, 16, delay_dist="geometric")
            assert not drops.any()

    def test_validation(self):
        with pytest.raises(ValueError, match="delay_dist"):
            S.async_delay_stream(0, 0, 4, delay_dist="pareto")
        with pytest.raises(ValueError, match="max_delay"):
            S.async_delay_stream(0, 0, 4, max_delay=-1)
        with pytest.raises(ValueError, match="dropout_rate"):
            S.async_delay_stream(0, 0, 4, dropout_rate=1.0)

    def test_stream_disjoint_from_participation_and_training_keys(self):
        """The async salt produces a key stream distinct from both the
        participation stream (salt 0x9A57) and the raw training key — no
        accidental correlation between the schedules."""
        seed, t = 0, 5
        k_async = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(seed), S._ASYNC_SALT), t)
        k_part = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(seed), 0x9A57), t)
        k_train = jax.random.key(seed)
        data = lambda k: np.asarray(jax.random.key_data(k))  # noqa: E731
        assert not np.array_equal(data(k_async), data(k_part))
        assert not np.array_equal(data(k_async), data(k_train))
        assert not np.array_equal(data(k_part), data(k_train))


class TestSchedule:
    def test_b_equals_m_zero_delay_flushes_every_round_with_unit_weights(self):
        agg = S.AsyncAggregator(buffer_size=6, delay_dist="zero")
        for t in range(8):
            assert agg.phase(t, 6) == 1
            w = np.asarray(agg.round_weights(t, 6))
            np.testing.assert_array_equal(w, np.ones(6, np.float32))

    @pytest.mark.parametrize("dist,drop", [("zero", 0.0), ("uniform", 0.0),
                                           ("geometric", 0.2)])
    def test_matches_independent_oracle(self, dist, drop):
        """The vectorized incremental cache == a from-scratch per-client
        simulator, flush flags AND staleness weights, 24 rounds."""
        agg = S.AsyncAggregator(buffer_size=3, delay_dist=dist,
                                dropout_rate=drop, max_delay=4, seed=11)
        oracle = _schedule_oracle(11, 5, 3, dist, 4, drop, 24)
        for t, (flush, weights) in enumerate(oracle):
            assert agg.phase(t, 5) == int(flush), t
            got = agg.round_weights(t, 5)
            if weights is None:
                assert got is None
            else:
                np.testing.assert_array_equal(np.asarray(got), weights)

    def test_weights_are_fedbuff_staleness_discounts(self):
        """Every nonzero weight is exactly 1/sqrt(1+tau) for an integer
        staleness tau in [0, max over the horizon]."""
        agg = S.AsyncAggregator(buffer_size=2, delay_dist="geometric",
                                dropout_rate=0.3, seed=5)
        seen_stale = set()
        for t in range(30):
            w = agg.round_weights(t, 6)
            if w is None:
                continue
            w = np.asarray(w)
            for wi in w[w > 0]:
                tau = 1.0 / np.float32(wi) ** 2 - 1.0
                assert abs(tau - round(float(tau))) < 1e-5
                seen_stale.add(int(round(float(tau))))
        assert 0 in seen_stale          # fresh reports exist
        assert max(seen_stale) >= 1     # and genuinely stale ones too

    def test_mid_stream_query_replays_from_scratch(self):
        """Querying round 17 on a cold cache (the resume path) equals the
        value the warm sequential walk produced."""
        agg = S.AsyncAggregator(buffer_size=2, delay_dist="uniform",
                                dropout_rate=0.1, seed=9)
        warm = [(agg.phase(t, 4), agg.round_weights(t, 4)) for t in range(20)]
        S._ASYNC_SCHEDULES.clear()
        cold_f, cold_w = agg.phase(17, 4), agg.round_weights(17, 4)
        assert cold_f == warm[17][0]
        if warm[17][1] is None:
            assert cold_w is None
        else:
            np.testing.assert_array_equal(np.asarray(cold_w),
                                          np.asarray(warm[17][1]))

    def test_phase_is_binary(self):
        agg = S.AsyncAggregator(buffer_size=3, delay_dist="geometric",
                                dropout_rate=0.4, seed=2)
        assert {agg.phase(t, 8) for t in range(40)} <= {0, 1}

    def test_different_seeds_give_different_schedules(self):
        a = S.AsyncAggregator(buffer_size=2, delay_dist="geometric", seed=0)
        b = S.AsyncAggregator(buffer_size=2, delay_dist="geometric", seed=1)
        fa = [a.phase(t, 6) for t in range(16)]
        fb = [b.phase(t, 6) for t in range(16)]
        assert fa != fb


class TestAsyncAggregatorUnit:
    N, M_PER = 2, 2

    def _params(self):
        key = jax.random.key(1)
        return {"w": jax.random.normal(key, (4, 3, 2)),
                "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 2))}

    def _kw(self):
        return dict(adj=jnp.eye(self.N), num_servers=self.N,
                    m_per=self.M_PER)

    def test_validation(self):
        with pytest.raises(ValueError, match="buffer_size"):
            S.AsyncAggregator(buffer_size=0)
        with pytest.raises(ValueError, match="delay_dist"):
            S.AsyncAggregator(buffer_size=1, delay_dist="exp")
        with pytest.raises(ValueError, match="dropout_rate"):
            S.AsyncAggregator(buffer_size=1, dropout_rate=1.0)
        with pytest.raises(ValueError, match="max_delay"):
            S.AsyncAggregator(buffer_size=1, max_delay=-2)
        with pytest.raises(ValueError, match="never fill"):
            S.AsyncAggregator(buffer_size=9).phase(0, 4)

    def test_skip_round_is_identity(self):
        params = self._params()
        agg = S.AsyncAggregator(buffer_size=4)
        out = agg.aggregate(params, round=0, mask=None, **self._kw())
        assert out is params

    def test_flush_is_hand_computed_weighted_mean(self):
        """Explicit weights [1, .5 | 0, 0]: server 0 mixes 2:1, the
        zero-weight server keeps every client's own params."""
        params = self._params()
        w = jnp.asarray([1.0, 0.5, 0.0, 0.0], jnp.float32)
        agg = S.AsyncAggregator(buffer_size=2)
        out = agg.aggregate(params, round=1, mask=w, **self._kw())
        pw = np.asarray(params["w"])
        want0 = (1.0 * pw[0] + 0.5 * pw[1]) / 1.5
        np.testing.assert_allclose(np.asarray(out["w"])[0], want0, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out["w"])[1], want0, rtol=1e-6)
        # server 1 had nothing buffered: untouched, per client
        np.testing.assert_array_equal(np.asarray(out["w"])[2], pw[2])
        np.testing.assert_array_equal(np.asarray(out["w"])[3], pw[3])

    def test_unit_weights_match_fedavg_bitwise(self):
        """The anchor at the aggregator level: weights all 1.0 == the
        unmasked FedAvg path, bit for bit."""
        params = self._params()
        fedavg = S.FedAvgAggregator().aggregate(params, **self._kw())
        agg = S.AsyncAggregator(buffer_size=4)
        out = agg.aggregate(params, round=1,
                            mask=jnp.ones(4, jnp.float32), **self._kw())
        for a, b in zip(jax.tree.leaves(fedavg), jax.tree.leaves(out)):
            a, b = np.asarray(a), np.asarray(b)
            np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))

    def test_flush_ignores_adjacency(self):
        """The flush is per-server (cross-server spread flows through the
        shared imputation round, like FedAvg): any adj gives the same out."""
        params = self._params()
        w = jnp.asarray([1.0, 1.0, 0.5, 0.0], jnp.float32)
        agg = S.AsyncAggregator(buffer_size=2)
        a = agg.aggregate(params, round=1, mask=w, adj=jnp.eye(self.N),
                          num_servers=self.N, m_per=self.M_PER)
        b = agg.aggregate(params, round=1, mask=w,
                          adj=jnp.ones((self.N, self.N)),
                          num_servers=self.N, m_per=self.M_PER)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestGoldenAnchor:
    @pytest.fixture(scope="class")
    def fedgl_run(self, small):
        """One shared synchronous FedGL reference fit (state + history)."""
        batch, cfg = small
        return registry.build("FedGL", cfg, batch).fit(
            jax.random.key(0), batch, rounds=4)

    def test_b_equals_m_star_matches_fedgl_bitwise_and_golden(self, small,
                                                              fedgl_run):
        """spreadfgl_async(B=M, zero delay, 1 server) == FedGL: the full
        4-round histories are equal EXACTLY (not allclose), and both match
        the pinned golden."""
        batch, cfg = small
        _, hist_f = fedgl_run
        cfg_a = _sync_cfg(cfg, async_buffer=M)
        tr_a = registry.build("spreadfgl_async", cfg_a, batch, num_servers=1)
        _, hist_a = tr_a.fit(jax.random.key(0), batch, rounds=4)
        assert hist_a == hist_f                      # bit-identical histories
        for k, want in GOLDEN_FEDGL.items():
            np.testing.assert_allclose(hist_a[k], want, atol=1e-4,
                                       err_msg=f"async anchor[{k!r}] drifted")

    def test_b_equals_m_ring_matches_per_server_fedavg_bitwise(self, small):
        """N=2 anchor: async B=M zero-delay on a ring == the same engine
        with a plain FedAvgAggregator (per-server flush, weights 1.0)."""
        batch, cfg = small
        tr_sync = FGLTrainer(cfg, batch, topology=S.RingTopology(2),
                             aggregator=S.FedAvgAggregator(),
                             imputation=S.SpreadImputation())
        _, hist_s = tr_sync.fit(jax.random.key(0), batch, rounds=4)
        tr_a = make_spreadfgl_async(_sync_cfg(cfg, async_buffer=M), batch,
                                    num_servers=2)
        _, hist_a = tr_a.fit(jax.random.key(0), batch, rounds=4)
        assert hist_a == hist_s

    def test_b_below_m_diverges_without_touching_the_training_key(
            self, small, fedgl_run):
        """B < M under delays/dropouts genuinely changes training — yet after
        equal rounds the async state holds the SAME FGLState.key as the sync
        run: the delay stream is drawn entirely outside it."""
        batch, cfg = small
        st_f, hist_f = fedgl_run
        tr_a = registry.build("spreadfgl_async",
                              _sync_cfg(cfg, async_buffer=2,
                                        delay_dist="geometric",
                                        dropout_rate=0.2),
                              batch, num_servers=1)
        st_a, hist_a = tr_a.fit(jax.random.key(0), batch, rounds=4)
        assert np.isfinite(hist_a["loss"]).all()
        assert hist_a["acc"] != hist_f["acc"]
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(st_f.key)),
            np.asarray(jax.random.key_data(st_a.key)))


class TestResume:
    @pytest.mark.parametrize("dist,drop", [("geometric", 0.2),
                                           ("uniform", 0.0)])
    def test_fit6_equals_fit3_save_load_fit3(self, small, dist, drop):
        """Mid-buffer resume under delays and dropouts: the restored run
        replays the schedule from the checkpointed round exactly."""
        batch, cfg = small
        cfg = _sync_cfg(cfg, imputation_interval=2, async_buffer=2,
                        delay_dist=dist, dropout_rate=drop)
        tr = make_spreadfgl_async(cfg, batch, num_servers=2)
        _, full = tr.fit(jax.random.key(0), batch, rounds=6)
        state, first = tr.fit(jax.random.key(0), batch, rounds=3)
        path = os.path.join(tempfile.mkdtemp(), "async_resume.npz")
        io.save(path, state)
        restored = io.restore(path, tr.init(jax.random.key(0), batch))
        assert restored.round == 3
        # Drop the warm schedule cache: resume must NOT depend on this
        # process having walked rounds 0-2 already.
        S._ASYNC_SCHEDULES.clear()
        _, second = tr.fit(state=restored, rounds=3)
        assert first["loss"] + second["loss"] == full["loss"]
        assert first["acc"] + second["acc"] == full["acc"]
        assert first["f1"] + second["f1"] == full["f1"]

    def test_resume_composes_with_partial_participation(self, small):
        """rho < 1 AND async delays: both key streams key off the absolute
        round, so the combined schedule survives a checkpoint."""
        batch, cfg = small
        cfg = _sync_cfg(cfg, imputation_interval=2, async_buffer=2,
                        delay_dist="geometric", participation=0.5)
        tr = make_spreadfgl_async(cfg, batch, num_servers=2)
        _, full = tr.fit(jax.random.key(0), batch, rounds=4)
        state, first = tr.fit(jax.random.key(0), batch, rounds=2)
        path = os.path.join(tempfile.mkdtemp(), "async_part.npz")
        io.save(path, state)
        restored = io.restore(path, tr.init(jax.random.key(0), batch))
        _, second = tr.fit(state=restored, rounds=2)
        assert first["loss"] + second["loss"] == full["loss"]


class TestEngineThreading:
    def test_agg_mask_multiplies_participation_into_flush_weights(self, small):
        batch, cfg = small
        cfg = _sync_cfg(cfg, async_buffer=M, participation=0.5)
        tr = make_spreadfgl_async(cfg, batch, num_servers=1)
        t = 0   # B = M, zero delay: round 0 flushes with unit weights
        part = np.asarray(tr._participation_mask(t))
        flush = np.asarray(tr.aggregator.round_weights(t, tr.m))
        np.testing.assert_array_equal(np.asarray(tr._agg_mask(t)),
                                      part * flush)

    def test_agg_mask_none_on_skip_rounds(self, small):
        batch, cfg = small
        cfg = _sync_cfg(cfg, async_buffer=M, delay_dist="uniform", seed=4)
        tr = make_spreadfgl_async(cfg, batch, num_servers=1)
        skip = [t for t in range(12) if tr._agg_phase(t) == 0]
        assert skip, "uniform delays must produce at least one skip round"
        assert tr._agg_mask(skip[0]) is None

    def test_builder_validation(self, small):
        batch, cfg = small
        with pytest.raises(ValueError, match="async_buffer"):
            make_spreadfgl_async(cfg, batch)           # cfg.async_buffer = 0
        with pytest.raises(ValueError, match="never fill"):
            make_spreadfgl_async(_sync_cfg(cfg, async_buffer=99), batch)

    def test_one_server_uses_star_topology(self, small):
        batch, cfg = small
        tr = make_spreadfgl_async(_sync_cfg(cfg, async_buffer=2), batch,
                                  num_servers=1)
        assert isinstance(tr.topology, S.StarTopology)
        assert isinstance(tr.aggregator, S.AsyncAggregator)

    def test_registry_name_resolves(self):
        assert "spreadfgl_async" in registry.names()

    @pytest.mark.parametrize("name,kw", [
        ("local", {}), ("fedavg_fusion", {}), ("fedsage_plus", {}),
        ("FedGL", {}), ("SpreadFGL", {"num_servers": 2}),
        ("spreadfgl_gossip", {"num_servers": 2, "gossip_every": 2}),
        ("spreadfgl_async", {"num_servers": 2}),
    ])
    def test_every_registered_method_trains_with_async_buffer_set(
            self, small, name, kw):
        """cfg.async_buffer is inert for synchronous compositions and
        activates the buffered aggregator for spreadfgl_async — either way
        every registry method still trains."""
        batch, cfg = small
        cfg = _sync_cfg(cfg, async_buffer=2, delay_dist="geometric",
                        dropout_rate=0.1)
        tr = registry.build(name, cfg, batch, **kw)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=2)
        assert np.isfinite(hist["loss"]).all(), name
