"""The scale-up regime of the synthetic graph generator.

``scale > 1.0`` is the documented way to grow a Table-I dataset toward the
10k–1M-node range swept by ``benchmarks/bench_sim_scaling.py``; these tests
make that regime trustworthy: deterministic, monotone in size, statistically
an SBM (homophilous), and routed through the vectorized sampler — while the
``scale <= 1.0`` path keeps the historical per-edge rng stream that every
fixed-seed golden in the suite depends on.
"""
import numpy as np
import pytest

from repro.data import synthetic_graphs as sg
from repro.data.synthetic_graphs import DATASETS, DatasetStats, make_sbm_graph


class TestScaleUpRegime:
    def test_deterministic_at_scale_4(self):
        a = make_sbm_graph(DATASETS["cora"], scale=4.0, seed=3)
        b = make_sbm_graph(DATASETS["cora"], scale=4.0, seed=3)
        np.testing.assert_array_equal(a.x, b.x)
        np.testing.assert_array_equal(a.y, b.y)
        np.testing.assert_array_equal(a.senders, b.senders)
        np.testing.assert_array_equal(a.receivers, b.receivers)

    def test_node_and_edge_counts_monotone_in_scale(self):
        nodes, edges = [], []
        for s in (0.5, 1.0, 2.0, 4.0):
            g = make_sbm_graph(DATASETS["cora"], scale=s, seed=0)
            nodes.append(g.x.shape[0])
            edges.append(g.senders.size)
        assert nodes == sorted(nodes) and nodes[0] < nodes[-1], nodes
        assert edges == sorted(edges) and edges[0] < edges[-1], edges
        # Node counts track the requested scale exactly.
        assert nodes[1] == DATASETS["cora"].num_nodes
        assert nodes[3] == 4 * DATASETS["cora"].num_nodes

    def test_feature_dim_saturates_at_dataset_dim(self):
        """Growing n must not also inflate every feature row: d caps at the
        dataset's real feature_dim from scale 0.25 on."""
        d_ref = DATASETS["cora"].feature_dim
        for s in (0.25, 1.0, 4.0):
            g = make_sbm_graph(DATASETS["cora"], scale=s, seed=0)
            assert g.x.shape[1] == d_ref, (s, g.x.shape)
        small = make_sbm_graph(DATASETS["cora"], scale=0.1, seed=0)
        assert small.x.shape[1] < d_ref

    def test_scaled_up_graph_stays_homophilous(self):
        g = make_sbm_graph(DATASETS["cora"], scale=4.0, seed=0)
        intra = np.mean(g.y[g.senders] == g.y[g.receivers])
        # Dedup of intra-class duplicates pulls the realized fraction a bit
        # off the target; it must still be far above the ~1/c chance level.
        assert intra > 0.6, intra

    def test_million_node_stats_supported(self):
        """bench_sim_scaling's generator contract: custom stats + scale > 1
        produce the exact requested node count with no self-loops."""
        stats = DatasetStats("big", 25_000, 50_000, 32, 10, 0.7)
        g = make_sbm_graph(stats, scale=2.0, seed=0)
        assert g.x.shape == (50_000, 32)
        assert g.num_classes == 10
        assert (g.senders != g.receivers).all()
        assert g.senders.min() >= 0 and g.receivers.max() < 50_000


class TestSamplerRouting:
    def test_small_scale_uses_historical_loop_sampler(self, monkeypatch):
        calls = {"loop": 0, "vec": 0}
        orig_loop, orig_vec = sg._sample_edges_loop, sg._sample_edges_vectorized
        monkeypatch.setattr(sg, "_sample_edges_loop",
                            lambda *a: calls.__setitem__("loop", calls["loop"] + 1)
                            or orig_loop(*a))
        monkeypatch.setattr(sg, "_sample_edges_vectorized",
                            lambda *a: calls.__setitem__("vec", calls["vec"] + 1)
                            or orig_vec(*a))
        make_sbm_graph(DATASETS["cora"], scale=0.2, seed=0)
        make_sbm_graph(DATASETS["cora"], scale=1.0, seed=0)  # boundary: loop
        assert calls == {"loop": 2, "vec": 0}
        make_sbm_graph(DATASETS["cora"], scale=1.5, seed=0)
        assert calls == {"loop": 2, "vec": 1}

    def test_samplers_share_distribution(self):
        """Same SBM family: loop and vectorized samplers at matched size
        agree on edge count and intra-class fraction within noise."""
        stats = DATASETS["cora"]
        g_loop = make_sbm_graph(stats, scale=1.0, seed=0)
        big = DatasetStats(stats.name, stats.num_nodes // 2,
                           stats.num_edges // 2, stats.feature_dim,
                           stats.num_classes, stats.homophily)
        g_vec = make_sbm_graph(big, scale=2.0, seed=0)
        assert g_vec.x.shape[0] == g_loop.x.shape[0]
        n_edges = (g_loop.senders.size, g_vec.senders.size)
        assert abs(n_edges[0] - n_edges[1]) / max(n_edges) < 0.05, n_edges
        f_loop = np.mean(g_loop.y[g_loop.senders] == g_loop.y[g_loop.receivers])
        f_vec = np.mean(g_vec.y[g_vec.senders] == g_vec.y[g_vec.receivers])
        assert abs(f_loop - f_vec) < 0.05, (f_loop, f_vec)
