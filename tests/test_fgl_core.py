"""Unit tests for the paper's core components (Sec. III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import assessor as assessor_lib
from repro.core import gnn, imputation, partition, patcher
from repro.core.types import ClientBatch, FGLConfig
from repro.data.synthetic_graphs import DATASETS, load_dataset, make_sbm_graph


@pytest.fixture(scope="module")
def graph():
    return load_dataset("cora", scale=0.12, seed=0)


@pytest.fixture(scope="module")
def batch_and_assign(graph):
    return partition.partition_graph(graph, 6, aug_max=8, seed=0)


class TestPartition:
    def test_covers_all_nodes_disjointly(self, graph, batch_and_assign):
        batch, assign = batch_and_assign
        ids = np.asarray(batch.global_id)
        real = ids[ids >= 0]
        assert len(real) == graph.num_nodes          # Σ|V^ji| = n
        assert len(np.unique(real)) == graph.num_nodes  # no shared nodes

    def test_no_cross_client_edges(self, graph, batch_and_assign):
        batch, assign = batch_and_assign
        # every adjacency entry connects two nodes of the same client
        for ci in range(batch.num_clients):
            adj = np.asarray(batch.adj[ci])
            mask = np.asarray(batch.node_mask[ci])
            rows, cols = np.nonzero(adj)
            assert mask[rows].all() and mask[cols].all()

    def test_missing_links_counted(self, graph, batch_and_assign):
        _, assign = batch_and_assign
        miss = partition.count_missing_links(graph, assign)
        assert 0 < miss < graph.num_edges

    def test_balanced_sizes(self, graph, batch_and_assign):
        batch, _ = batch_and_assign
        sizes = np.asarray(batch.node_mask).sum(axis=1)
        assert sizes.min() >= 1
        assert sizes.max() <= 2.5 * sizes.mean()

    def test_ring_adjacency(self):
        a = partition.ring_adjacency(3)
        assert a.shape == (3, 3)
        np.testing.assert_array_equal(a, a.T)
        assert np.all(np.diag(a) == 1.0)
        assert a.sum() == 9  # ring of 3 == fully connected incl self

    def test_train_test_masks_disjoint(self, batch_and_assign):
        batch, _ = batch_and_assign
        overlap = np.asarray(batch.train_mask) * np.asarray(batch.test_mask)
        assert overlap.sum() == 0


class TestGNN:
    @pytest.mark.parametrize("kind", ["sage", "gcn", "gat"])
    def test_forward_shapes_and_masking(self, kind):
        key = jax.random.key(0)
        n, d, c = 20, 12, 4
        params = gnn.init_classifier(key, kind, [d, 16, c])
        x = jax.random.normal(key, (n, d))
        adj = (jax.random.uniform(jax.random.key(1), (n, n)) < 0.2).astype(jnp.float32)
        adj = jnp.maximum(adj, adj.T)
        mask = jnp.ones((n,)).at[-5:].set(0.0)
        out = gnn.apply_classifier(params, kind, x, adj, mask)
        assert out.shape == (n, c)
        assert np.all(np.asarray(out[-5:]) == 0.0)  # padded rows silent
        assert np.isfinite(np.asarray(out)).all()

    def test_padded_nodes_do_not_leak(self):
        """Changing padded-node features must not change real outputs."""
        key = jax.random.key(0)
        n, d, c = 16, 8, 3
        params = gnn.init_classifier(key, "sage", [d, 8, c])
        adj = jnp.ones((n, n)) - jnp.eye(n)
        mask = jnp.ones((n,)).at[10:].set(0.0)
        x1 = jax.random.normal(key, (n, d))
        x2 = x1.at[10:].add(100.0)
        o1 = gnn.apply_classifier(params, "sage", x1, adj, mask)
        o2 = gnn.apply_classifier(params, "sage", x2, adj, mask)
        np.testing.assert_allclose(np.asarray(o1[:10]), np.asarray(o2[:10]),
                                   atol=1e-5)


IMPLS = ("reference", "pallas_interpret")


class TestImputation:
    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_similarity_topk_cross_subgraph_only(self, kernel_impl):
        m, n_pad, c, k = 3, 8, 4, 3
        h = jax.random.normal(jax.random.key(0), (m * n_pad, c))
        mask = jnp.ones((m * n_pad,))
        cid = imputation.client_of_flat(m, n_pad)
        scores, idx = imputation.similarity_topk(h, mask, cid, k, block=8,
                                                 kernel_impl=kernel_impl)
        idx_np = np.asarray(idx)
        cid_np = np.asarray(cid)
        for u in range(m * n_pad):
            for j in range(k):
                v = idx_np[u, j]
                if v >= 0:
                    assert cid_np[u] != cid_np[v], "intra-client link imputed"

    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_topk_masks_padding(self, kernel_impl):
        m, n_pad, c, k = 2, 6, 3, 2
        h = jax.random.normal(jax.random.key(0), (m * n_pad, c))
        mask = jnp.zeros((m * n_pad,)).at[:4].set(1.0)  # only client0 slots real
        cid = imputation.client_of_flat(m, n_pad)
        scores, idx = imputation.similarity_topk(h, mask, cid, k, block=4,
                                                 kernel_impl=kernel_impl)
        # real rows may only link to real slots
        assert np.all(np.asarray(idx)[np.asarray(idx) >= 0] < 6)

    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_topk_k_exceeds_valid_candidates(self, kernel_impl):
        """k > cross-subgraph candidate count: spare slots get idx -1/score 0."""
        m, n_pad, c, k = 2, 4, 3, 6            # 4 cross candidates, k=6
        h = jax.random.normal(jax.random.key(1), (m * n_pad, c))
        mask = jnp.ones((m * n_pad,))
        cid = imputation.client_of_flat(m, n_pad)
        scores, idx = imputation.similarity_topk(h, mask, cid, k, block=4,
                                                 kernel_impl=kernel_impl)
        idx_np, sc_np = np.asarray(idx), np.asarray(scores)
        assert idx_np.shape == (m * n_pad, k)
        # exactly n_pad valid targets per row (the other client's slots)
        assert (np.sum(idx_np >= 0, axis=1) == n_pad).all()
        assert ((idx_np[:, n_pad:] == -1) & (sc_np[:, n_pad:] == 0.0)).all()
        assert np.isfinite(sc_np).all()

    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_topk_fully_masked_rows(self, kernel_impl):
        """Rows with mask 0 / zero valid targets yield all idx -1, score 0."""
        m, n_pad, c, k = 2, 4, 3, 2
        h = jax.random.normal(jax.random.key(2), (m * n_pad, c))
        mask = jnp.zeros((m * n_pad,))          # nothing is real
        cid = imputation.client_of_flat(m, n_pad)
        scores, idx = imputation.similarity_topk(h, mask, cid, k, block=4,
                                                 kernel_impl=kernel_impl)
        assert np.all(np.asarray(idx) == -1)
        assert np.all(np.asarray(scores) == 0.0)

    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_topk_target_mask_restricts_targets(self, kernel_impl):
        """target_mask shrinks the candidate set without masking source rows."""
        m, n_pad, c, k = 2, 6, 3, 2
        n_local = 4
        h = jax.random.normal(jax.random.key(3), (m * n_pad, c))
        mask = jnp.ones((m * n_pad,))           # every slot is a valid source
        tmask = mask * imputation.local_slot_mask(m, n_pad, n_local)
        cid = imputation.client_of_flat(m, n_pad)
        scores, idx = imputation.similarity_topk(
            h, mask, cid, k, block=4, kernel_impl=kernel_impl,
            target_mask=tmask)
        idx_np = np.asarray(idx)
        chosen = idx_np[idx_np >= 0]
        assert (chosen % n_pad < n_local).all()  # no aug-slot targets
        assert (np.sum(idx_np >= 0, axis=1) > 0).all()  # rows still link

    def test_topk_unknown_impl_rejected(self):
        h = jnp.zeros((4, 2))
        with pytest.raises(ValueError, match="kernel_impl"):
            imputation.similarity_topk(h, jnp.ones(4),
                                       jnp.zeros(4, jnp.int32), 1,
                                       kernel_impl="cuda")

    @pytest.mark.parametrize("kernel_impl", IMPLS)
    def test_topk_impls_agree(self, kernel_impl):
        """Both impls agree with each other on a mixed-mask problem."""
        m, n_pad, c, k = 3, 10, 5, 4           # n=30: not a block multiple
        h = jax.random.normal(jax.random.key(4), (m * n_pad, c))
        mask = (jax.random.uniform(jax.random.key(5), (m * n_pad,)) < 0.8
                ).astype(jnp.float32)
        cid = imputation.client_of_flat(m, n_pad)
        s_ref, i_ref = imputation.similarity_topk(h, mask, cid, k, block=8,
                                                  kernel_impl="reference")
        s, i = imputation.similarity_topk(h, mask, cid, k, block=8,
                                          kernel_impl=kernel_impl)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))

    def test_autoencoder_roundtrip_shapes(self):
        c, d = 5, 17
        ae = imputation.init_autoencoder(jax.random.key(0), c, d)
        s = imputation.sample_noise(jax.random.key(1), 11, c)
        x_bar, h_bar = imputation.reconstruct(ae, s)
        assert x_bar.shape == (11, d)
        assert h_bar.shape == (11, c)
        np.testing.assert_allclose(np.asarray(h_bar.sum(-1)), 1.0, atol=1e-5)


class TestAssessorLosses:
    def setup_method(self, _):
        self.c = 5
        self.asr = assessor_lib.init_assessor(jax.random.key(0), self.c)
        self.h_real = jax.nn.softmax(
            jax.random.normal(jax.random.key(1), (13, self.c)), -1)
        self.h_fake = jax.nn.softmax(
            jax.random.normal(jax.random.key(2), (13, self.c)), -1)
        self.mask = jnp.ones((13,))
        self.e = assessor_lib.negative_mask(self.h_real, 1.0 / self.c)

    def test_negative_mask_threshold(self):
        e = np.asarray(self.e)
        h = np.asarray(self.h_real)
        assert ((h > 0.2) == (e > 0)).all()

    def test_assessor_score_in_unit_interval(self):
        s = assessor_lib.apply_assessor(self.asr, self.h_real)
        assert np.all((np.asarray(s) > 0) & (np.asarray(s) < 1))

    def test_assessor_loss_decreases_when_training(self):
        """One gradient step on L_AS improves real/fake separation."""
        from repro.optim.adam import Adam
        opt = Adam(lr=1e-2)
        st = opt.init(self.asr)
        loss0 = assessor_lib.assessor_loss(self.asr, self.h_real, self.h_fake,
                                           self.e, self.mask)
        p = self.asr
        for _ in range(20):
            g = jax.grad(assessor_lib.assessor_loss)(p, self.h_real,
                                                     self.h_fake, self.e,
                                                     self.mask)
            p, st = opt.update(g, st, p)
        loss1 = assessor_lib.assessor_loss(p, self.h_real, self.h_fake,
                                           self.e, self.mask)
        assert float(loss1) < float(loss0)

    def test_ae_loss_masks_reconstruction(self):
        """Eq.14 reconstruction term only covers negative (e=0) attributes."""
        ae = imputation.init_autoencoder(jax.random.key(3), self.c, 7)
        s = imputation.sample_noise(jax.random.key(4), 13, self.c)
        all_pos = jnp.ones_like(self.h_real)      # e=1 everywhere -> no rec term
        l_pos = assessor_lib.autoencoder_loss(ae, self.asr, s, self.h_real,
                                              all_pos, self.mask)
        all_neg = jnp.zeros_like(self.h_real)     # e=0 -> pure reconstruction
        l_neg = assessor_lib.autoencoder_loss(ae, self.asr, s, self.h_real,
                                              all_neg, self.mask)
        assert np.isfinite(float(l_pos)) and np.isfinite(float(l_neg))
        # with e=0 the adversarial input is zeroed: Assor(0) constant
        s0 = assessor_lib.apply_assessor(self.asr, jnp.zeros_like(self.h_real))
        assert np.allclose(np.asarray(s0), np.asarray(s0)[0])


class TestPatcher:
    def test_fix_graphs_wires_aug_slots(self):
        m, n_local, aug, d, c, k = 2, 4, 2, 6, 3, 2
        n_pad = n_local + aug
        x = jnp.zeros((m, n_pad, d))
        adj = jnp.zeros((m, n_pad, n_pad))
        mask = jnp.zeros((m, n_pad)).at[:, :n_local].set(1.0)
        batch = ClientBatch(
            x=x, adj=adj, y=-jnp.ones((m, n_pad), jnp.int32),
            node_mask=mask, train_mask=jnp.zeros((m, n_pad)),
            test_mask=jnp.zeros((m, n_pad)),
            global_id=jnp.arange(m * n_pad).reshape(m, n_pad),
            num_classes=c, aug_max=aug)
        scores = jnp.ones((m * n_pad, k))
        # node 0 of client 0 links to node (1, 0) -> flat 6; others invalid
        idx = -jnp.ones((m * n_pad, k), jnp.int32)
        idx = idx.at[0, 0].set(n_pad)  # flat id of client1 slot0
        x_bar = jnp.arange(m * n_pad * d, dtype=jnp.float32).reshape(m * n_pad, d)
        fixed = patcher.fix_graphs(batch, scores, idx, x_bar)
        adj0 = np.asarray(fixed.adj[0])
        # aug slot got connected to source node 0 symmetrically
        aug_rows = np.nonzero(np.asarray(fixed.node_mask[0])[n_local:])[0] + n_local
        assert len(aug_rows) == 1
        ar = aug_rows[0]
        assert adj0[0, ar] == 1.0 and adj0[ar, 0] == 1.0
        np.testing.assert_allclose(np.asarray(fixed.x[0, ar]),
                                   np.asarray(x_bar[n_pad]))

    def test_clear_augmentation(self):
        g = load_dataset("cora", scale=0.08, seed=1)
        batch, _ = partition.partition_graph(g, 3, aug_max=4, seed=0)
        batch = jax.tree.map(jnp.asarray, batch)
        cleared = patcher.clear_augmentation(batch)
        n_local = cleared.n_local_max
        assert np.all(np.asarray(cleared.node_mask)[:, n_local:] == 0)


class TestSyntheticData:
    def test_deterministic(self):
        g1 = make_sbm_graph(DATASETS["citeseer"], scale=0.1, seed=7)
        g2 = make_sbm_graph(DATASETS["citeseer"], scale=0.1, seed=7)
        np.testing.assert_array_equal(np.asarray(g1.x), np.asarray(g2.x))
        np.testing.assert_array_equal(g1.senders, g2.senders)

    def test_stats_match_table1_proportions(self):
        for name, stats in DATASETS.items():
            g = make_sbm_graph(stats, scale=0.1, seed=0)
            assert g.num_classes == stats.num_classes
            assert abs(g.num_nodes - 0.1 * stats.num_nodes) < 0.02 * stats.num_nodes + 200

    def test_homophily_above_random(self):
        g = make_sbm_graph(DATASETS["cora"], scale=0.2, seed=0)
        y = np.asarray(g.y)
        same = (y[np.asarray(g.senders)] == y[np.asarray(g.receivers)]).mean()
        assert same > 2.0 / g.num_classes
