"""Coverage backfill for ``repro.checkpoint.io`` error paths and edge cases.

``tests/test_infra.py`` pins the happy-path round-trips; these exercise the
branches the first coverage run flagged: typed-PRNG-key shape validation,
python-scalar restore semantics, parent-directory creation, the ``_root``
path of a bare-leaf tree, and dtype restoration.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io


class TestKeyArrays:
    def test_key_array_roundtrip(self, tmp_path):
        tree = {"key": jax.random.key(42), "w": jnp.ones((3,))}
        path = tmp_path / "k.npz"
        io.save(path, tree)
        out = io.restore(path, {"key": jax.random.key(0), "w": jnp.zeros((3,))})
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(out["key"])),
            np.asarray(jax.random.key_data(tree["key"])))
        # the restored key is a usable typed key, not raw uint32 data
        jax.random.uniform(out["key"], (2,))

    def test_key_array_shape_mismatch_raises(self, tmp_path):
        path = tmp_path / "k.npz"
        io.save(path, {"key": jax.random.split(jax.random.key(0), 4)})
        with pytest.raises(ValueError, match="shape mismatch"):
            io.restore(path, {"key": jax.random.key(0)})

    def test_batched_key_roundtrip(self, tmp_path):
        keys = jax.random.split(jax.random.key(7), 3)
        path = tmp_path / "kb.npz"
        io.save(path, {"keys": keys})
        out = io.restore(path, {"keys": jax.random.split(jax.random.key(0), 3)})
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(out["keys"])),
            np.asarray(jax.random.key_data(keys)))


class TestScalars:
    def test_python_int_restores_as_python_int(self, tmp_path):
        path = tmp_path / "s.npz"
        io.save(path, {"round": 17, "lr": 0.5})
        out = io.restore(path, {"round": 0, "lr": 0.0})
        assert out["round"] == 17 and type(out["round"]) is int
        assert out["lr"] == 0.5 and type(out["lr"]) is float

    def test_registered_dataclass_scalar_field(self, tmp_path):
        @jax.tree_util.register_dataclass
        @dataclasses.dataclass
        class St:
            w: jnp.ndarray
            round: int = 0

        path = tmp_path / "dc.npz"
        io.save(path, St(w=jnp.arange(4.0), round=9))
        out = io.restore(path, St(w=jnp.zeros(4)))
        assert out.round == 9 and type(out.round) is int
        np.testing.assert_array_equal(np.asarray(out.w), np.arange(4.0))


class TestStructure:
    def test_save_creates_parent_directories(self, tmp_path):
        path = tmp_path / "a" / "b" / "c.npz"
        io.save(path, {"w": jnp.ones((2,))})
        assert path.exists()

    def test_bare_leaf_uses_root_path(self, tmp_path):
        path = tmp_path / "root.npz"
        io.save(path, jnp.arange(5.0))
        out = io.restore(path, jnp.zeros(5))
        np.testing.assert_array_equal(np.asarray(out), np.arange(5.0))

    def test_missing_leaf_names_the_path(self, tmp_path):
        path = tmp_path / "m.npz"
        io.save(path, {"a": jnp.ones(2)})
        with pytest.raises(KeyError, match="missing leaf 'b'"):
            io.restore(path, {"a": jnp.zeros(2), "b": jnp.zeros(2)})

    def test_array_shape_mismatch_names_the_path(self, tmp_path):
        path = tmp_path / "sm.npz"
        io.save(path, {"w": jnp.ones((2, 3))})
        with pytest.raises(ValueError, match="w"):
            io.restore(path, {"w": jnp.zeros((3, 2))})

    def test_restore_casts_to_template_dtype(self, tmp_path):
        path = tmp_path / "d.npz"
        io.save(path, {"w": jnp.arange(4, dtype=jnp.int32)})
        out = io.restore(path, {"w": jnp.zeros(4, jnp.float32)})
        assert out["w"].dtype == np.float32

    def test_nested_tuple_and_list_nodes(self, tmp_path):
        tree = {"layers": [(jnp.ones((2,)), jnp.zeros((3,))),
                           (jnp.full((2,), 2.0), jnp.full((3,), 3.0))]}
        path = tmp_path / "n.npz"
        io.save(path, tree)
        template = {"layers": [(jnp.zeros((2,)), jnp.zeros((3,))),
                               (jnp.zeros((2,)), jnp.zeros((3,)))]}
        out = io.restore(path, template)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
