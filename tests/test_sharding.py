"""Sharding rules + spec construction (divisibility fallbacks, mesh plumbing)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (enough for rules)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


class TestLogicalToSpec:
    def test_basic_tp(self):
        mesh = FakeMesh(data=16, model=16)
        spec = rules.logical_to_spec(("embed", "heads"), (4096, 4096), mesh)
        assert spec == P("data", "model")

    def test_divisibility_fallback(self):
        mesh = FakeMesh(data=16, model=16)
        # 25 heads don't divide 16 -> replicated
        spec = rules.logical_to_spec(("embed", "heads"), (1600, 25 * 64), mesh)
        assert spec == P("data", "model")  # 1600/16 ok, 1600 total head dim ok
        spec = rules.logical_to_spec((None, "heads"), (7, 25), mesh)
        assert spec == P(None, None)

    def test_axis_used_once(self):
        mesh = FakeMesh(data=16, model=16)
        spec = rules.logical_to_spec(("ff", "heads"), (1024, 1024), mesh)
        assert spec == P("model", None)  # second 'model' consumer loses

    def test_experts_shard_when_divisible(self):
        mesh = FakeMesh(data=16, model=16)
        spec = rules.logical_to_spec(("experts", "embed", "expert_ff"),
                                     (64, 2048, 1024), mesh)
        assert spec == P("model", "data", None)  # model consumed by experts

    def test_experts_fallback_mixtral(self):
        mesh = FakeMesh(data=16, model=16)
        spec = rules.logical_to_spec(("experts", "embed", "expert_ff"),
                                     (8, 4096, 14336), mesh)
        assert spec == P(None, "data", "model")

    def test_batch_axes_multi_pod(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        assert rules.batch_axes(mesh) == ("pod", "data")
        spec = rules.logical_to_spec(("batch", None), (256, 4096), mesh)
        assert spec == P(("pod", "data"), None)

    def test_layers_never_sharded(self):
        mesh = FakeMesh(data=16, model=16)
        spec = rules.logical_to_spec(("layers", "embed", "ff"),
                                     (32, 4096, 14336), mesh)
        assert spec == P(None, "data", "model")


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax.sharding, "set_mesh"),
    reason="jax.sharding.set_mesh landed after this jax version "
           f"({jax.__version__}); the subprocess inherits the same jax")
def test_multi_device_lowering_subprocess():
    """End-to-end spec plumbing on 8 forced host devices (subprocess so the
    main test process keeps its single-device jax)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_config, INPUT_SHAPES, InputShape
        from repro.launch.dryrun import build_lowerable
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen3-4b", "smoke")
        shape = InputShape("t", 64, 8, "train")
        fn, args = build_lowerable(cfg, shape, mesh)
        with jax.sharding.set_mesh(mesh):
            compiled = jax.jit(fn).lower(*args).compile()
        print("OK", compiled.cost_analysis()["flops"] > 0)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "OK True" in out.stdout, out.stderr[-2000:]


def test_collective_bytes_parser():
    from repro.roofline.analysis import collective_bytes
    text = """
  %all-gather = f32[512,128]{1,0} all-gather(%p), replica_groups=[4,4]<=[4,4]T(1,0), dimensions={0}
  %all-reduce = f32[128,512]{1,0} all-reduce(%d), replica_groups=[4,4]<=[4,4]T(1,0), to_apply=%add
  %reduce-scatter = bf16[32,16]{1,0} reduce-scatter(%q), replica_groups=[2,8]<=[16]
  %cp = f32[64]{0} collective-permute(%x), source_target_pairs={{0,1}}
  %other = f32[9] add(%a, %b)
"""
    got = collective_bytes(text)
    assert got["all-gather"] == 512 * 128 * 4 // 4
    assert got["all-reduce"] == 128 * 512 * 4
    assert got["reduce-scatter"] == 32 * 16 * 2 * 8
    assert got["collective-permute"] == 64 * 4
    assert got["all-to-all"] == 0


def test_roofline_terms():
    from repro.configs import INPUT_SHAPES
    from repro.roofline.analysis import RooflineRecord
    rec = RooflineRecord(arch="x", shape="train_4k", mesh="single", chips=256,
                         flops=197e12, hbm_bytes=819e9, coll_bytes={"all-reduce": 50e9},
                         model_flops=197e12 * 256)
    assert abs(rec.compute_s - 1.0) < 1e-9
    assert abs(rec.memory_s - 1.0) < 1e-9
    assert abs(rec.collective_s - 1.0) < 1e-9
    assert rec.useful_flops_ratio == 1.0
    assert rec.dominant in ("compute", "memory", "collective")


@pytest.mark.slow
def test_hlo_cost_loop_correction_subprocess():
    """Loop-aware analyzer: scanned and unrolled lowerings of the same model
    must report (near-)identical FLOPs, while XLA's cost_analysis undercounts
    the scanned one."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro import configs
        from repro.train.step import make_train_step, init_state
        from repro.optim.adam import Adam
        from repro.roofline.hlo_cost import analyze_text

        def measure(scan):
            cfg = dataclasses.replace(configs.get_config("qwen3-4b", "smoke"),
                                      scan_layers=scan, remat=True)
            opt = Adam(lr=1e-3)
            state = init_state(jax.random.key(0), cfg, opt)
            batch = {"tokens": jnp.zeros((4, 64), jnp.int32)}
            comp = jax.jit(make_train_step(cfg, opt)).lower(state, batch).compile()
            return analyze_text(comp.as_text())["flops"]

        a, b = measure(True), measure(False)
        assert abs(a / b - 1.0) < 0.05, (a, b)
        print("HLO-COST-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "HLO-COST-OK" in out.stdout, out.stderr[-2000:]
