"""Shared pytest config. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the host's single device; only dryrun.py forces 512."""
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")
