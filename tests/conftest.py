"""Shared pytest config. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the host's single device; only dryrun.py forces 512.

The expensive fixed-seed SBM graph / partitioned batch that most suites
train on are session-scoped here: every module used to rebuild the
identical `small` setup (same scale/seed/noise arguments), which dominated
suite wall time. Fixtures only hand out *read-only* values (tests replace
configs with ``dataclasses.replace`` and never mutate the batch), so
sharing one instance across modules is safe.
"""
import pytest

from repro.core.partition import partition_graph
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")


@pytest.fixture(scope="session")
def sbm_graph_small():
    """The fixed-seed reduced-scale cora stand-in every suite trains on."""
    return make_sbm_graph(DATASETS["cora"], scale=0.10, seed=1,
                          feature_noise=3.0, signal_ratio=0.5)


@pytest.fixture(scope="session")
def small_batch(sbm_graph_small):
    """Its canonical 4-client partition (aug 8, seed 0, 30% labels)."""
    batch, _ = partition_graph(sbm_graph_small, 4, aug_max=8, seed=0,
                               label_ratio=0.3)
    return batch


@pytest.fixture(scope="session")
def small(small_batch):
    """Fixed-seed 2-server / 4-client batch (fast enough for many fits)."""
    cfg = FGLConfig(hidden_dim=16, local_rounds=2, imputation_interval=1,
                    top_k_links=3, aug_max=8)
    return small_batch, cfg
