"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(42)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,window", [
    (1, 2, 2, 128, 64, None),
    (2, 4, 2, 256, 64, None),      # GQA 2:1
    (1, 8, 1, 128, 128, None),     # MQA
    (2, 4, 4, 200, 64, 64),        # ragged seq + sliding window
    (1, 2, 2, 384, 32, 128),
])
def test_flash_attention_matches_oracle(b, hq, hkv, s, d, window, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, s + d + hq), 3)
    q = _rand(ks[0], (b, hq, s, d), dtype)
    k = _rand(ks[1], (b, hkv, s, d), dtype)
    v = _rand(ks[2], (b, hkv, s, d), dtype)
    out = ops.mha(q, k, v, causal=True, window=window, interpret=True)
    kk = jnp.repeat(k, hq // hkv, axis=1)
    vv = jnp.repeat(v, hq // hkv, axis=1)
    expect = ref.flash_attention(q, kk, vv, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_first_row_attends_self_only():
    q = _rand(KEY, (1, 1, 128, 32), jnp.float32)
    k = _rand(jax.random.fold_in(KEY, 1), (1, 1, 128, 32), jnp.float32)
    v = _rand(jax.random.fold_in(KEY, 2), (1, 1, 128, 32), jnp.float32)
    out = ops.mha(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d", [(64, 32), (100, 70), (256, 128), (300, 129), (37, 5)])
def test_sage_aggregate_matches_oracle(n, d, dtype):
    a = (jax.random.uniform(jax.random.fold_in(KEY, n), (n, n)) < 0.15
         ).astype(dtype)
    h = _rand(jax.random.fold_in(KEY, n + d), (n, d), dtype)
    out = ops.sage_aggregate(a, h, interpret=True)
    expect = ref.sage_aggregate(a, h)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_sage_aggregate_isolated_nodes_zero():
    """Zero-degree rows must output zeros (degree clamp, not NaN)."""
    n, d = 64, 16
    a = jnp.zeros((n, n), jnp.float32)
    h = _rand(KEY, (n, d), jnp.float32)
    out = ops.sage_aggregate(a, h, interpret=True)
    assert np.all(np.asarray(out) == 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,c", [(64, 300, 7), (128, 1024, 15), (10, 33, 6),
                                   (256, 512, 10)])
def test_sim_block_matches_oracle(b, n, c, dtype):
    rows = _rand(jax.random.fold_in(KEY, b), (b, c), dtype)
    h = _rand(jax.random.fold_in(KEY, b + n), (n, c), dtype)
    out = ops.sim_block(rows, h, interpret=True)
    expect = ref.sim_block(rows, h)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_sim_block_gram_symmetry():
    h = _rand(KEY, (96, 7), jnp.float32)
    gram = ops.sim_block(h, h, interpret=True)
    np.testing.assert_allclose(np.asarray(gram), np.asarray(gram).T,
                               atol=1e-5, rtol=1e-5)


def _sim_topk_oracle(h, cid, tmask, k):
    """Unfused ground truth: full masked gram + jax.lax.top_k."""
    gram = h.astype(jnp.float32) @ h.astype(jnp.float32).T
    gram = jnp.where(cid[:, None] == cid[None, :], -jnp.inf, gram)
    gram = jnp.where(tmask[None, :] > 0, gram, -jnp.inf)
    return jax.lax.top_k(gram, k)


@pytest.mark.parametrize("n,c,k,bm,bn", [
    (64, 5, 3, 16, 32),
    (128, 15, 5, 128, 512),     # block-multiple fast path
    (100, 7, 5, 32, 64),        # non-block-multiple n
    (37, 4, 3, 8, 16),          # tiny + non-multiple
])
def test_sim_topk_fused_matches_oracle(n, c, k, bm, bn):
    ks = jax.random.split(jax.random.fold_in(KEY, n + c), 2)
    h = _rand(ks[0], (n, c), jnp.float32)
    cid = (jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0) // max(n // 4, 1)
           ).squeeze(-1)
    tmask = (jax.random.uniform(ks[1], (n,)) < 0.7).astype(jnp.float32)
    vals, idx = ops.sim_topk(h, cid, tmask, k, block_m=bm, block_n=bn,
                             interpret=True)
    ovals, oidx = _sim_topk_oracle(h, cid, tmask, k)
    fin = np.isfinite(np.asarray(ovals))
    np.testing.assert_allclose(np.asarray(vals)[fin], np.asarray(ovals)[fin],
                               atol=1e-5, rtol=1e-5)
    # idx only comparable where the score is real; the fused kernel keeps -1
    # on unfilled slots while top_k emits arbitrary indices there.
    np.testing.assert_array_equal(np.asarray(idx)[fin], np.asarray(oidx)[fin])
    assert np.all(np.isneginf(np.asarray(vals)[~fin]))
    assert np.all(np.asarray(idx)[~fin] == -1)


def test_sim_topk_fused_fully_masked_rows_keep_minus_one():
    n, c, k = 24, 4, 3
    h = _rand(KEY, (n, c), jnp.float32)
    cid = jnp.zeros((n,), jnp.int32)            # everything same client
    vals, idx = ops.sim_topk(h, cid, jnp.ones((n,)), k, block_m=8, block_n=8,
                             interpret=True)
    assert np.all(np.asarray(idx) == -1)
    assert np.all(np.isneginf(np.asarray(vals)))


def test_sim_topk_fused_unfilled_slots_stay_minus_one_across_tiles():
    """One valid candidate, k=3, several column tiles: the merge must not
    resurrect stale indices for exhausted slots in later tiles."""
    n, c, k = 32, 4, 3
    h = _rand(KEY, (n, c), jnp.float32)
    cid = jnp.zeros((n,), jnp.int32).at[5].set(1)   # node 5 is the only target
    vals, idx = ops.sim_topk(h, cid, jnp.ones((n,)), k, block_m=8, block_n=16,
                             interpret=True)
    idx_np, vals_np = np.asarray(idx), np.asarray(vals)
    assert np.all(idx_np[:5, 0] == 5) and np.all(idx_np[6:, 0] == 5)
    assert np.all(idx_np[:, 1:][np.isneginf(vals_np[:, 1:])] == -1)
    assert np.all(vals_np[:5, 1:] == -np.inf)


def test_sim_topk_fused_under_vmap():
    """The [N] server axis: vmapped fused kernel == per-slice calls."""
    n_srv, n, c, k = 3, 40, 5, 4
    h = _rand(KEY, (n_srv, n, c), jnp.float32)
    cid = jnp.repeat(jnp.arange(2, dtype=jnp.int32), n // 2)
    tmask = jnp.ones((n,))
    f = jax.vmap(lambda hj: ops.sim_topk(hj, cid, tmask, k, block_m=8,
                                         block_n=16, interpret=True))
    vals, idx = f(h)
    for j in range(n_srv):
        v_j, i_j = ops.sim_topk(h[j], cid, tmask, k, block_m=8, block_n=16,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(vals[j]), np.asarray(v_j),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(idx[j]), np.asarray(i_j))


class TestKernelPipelineIntegration:
    """Kernels swapped into the real FGL pipeline (interpret mode)."""

    def test_sage_kernel_in_classifier(self):
        from repro.core import gnn
        key = jax.random.key(0)
        n, d, c = 40, 12, 5
        params = gnn.init_classifier(key, "sage", [d, 16, c])
        x = jax.random.normal(key, (n, d))
        adj = (jax.random.uniform(jax.random.fold_in(key, 1), (n, n)) < 0.2
               ).astype(jnp.float32)
        adj = jnp.maximum(adj, adj.T)
        mask = jnp.ones((n,))
        ref_out = gnn.apply_classifier(params, "sage", x, adj, mask,
                                       impl="reference")
        pls_out = gnn.apply_classifier(params, "sage", x, adj, mask,
                                       impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(ref_out), np.asarray(pls_out),
                                   atol=1e-4, rtol=1e-4)

    def test_sim_kernel_in_imputation(self):
        from repro.core import imputation
        key = jax.random.key(0)
        c = 5
        h = jax.nn.softmax(jax.random.normal(key, (64, c)), -1)
        fm = jnp.ones((64,))
        cid = imputation.client_of_flat(4, 16)
        s1, i1 = imputation.similarity_topk(h, fm, cid, 3,
                                            kernel_impl="reference", block=32)
        s2, i2 = imputation.similarity_topk(h, fm, cid, 3,
                                            kernel_impl="pallas_interpret",
                                            block=32)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_chunked_attention_matches_reference(self):
        from repro.models.attention import _sdpa, _sdpa_chunked
        key = jax.random.key(0)
        for (b, h, s, d, w) in [(1, 2, 256, 32, 0), (2, 4, 128, 16, 48)]:
            q = jax.random.normal(key, (b, h, s, d))
            k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d))
            v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, d))
            a = _sdpa(q, k, v, causal=True, window=w)
            c = _sdpa_chunked(q, k, v, causal=True, window=w, chunk=64)
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       atol=1e-5, rtol=1e-5)
