"""docs/ stays truthful: every path referenced from PAPER_MAP.md,
ARCHITECTURE.md, and BENCHMARKS.md exists, `file:line` anchors point inside
their file, every symbol a PAPER_MAP table row names still appears in the
file(s) that row references, and BENCHMARKS.md stays in lockstep with the
benchmark suite (every bench module documented; every result file and flag
it mentions actually produced/accepted by the code). This is the CI docs
job (see .github/workflows/ci.yml)."""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"

# `path` or `path:line` references inside backticks.
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs)/[\w./-]+\.(?:py|md|json|yml))"
    r"(?::(\d+))?`")
# Identifier-ish backticked tokens (symbols, possibly dotted); excludes
# anything with '/', '-', or spaces (paths, CLI flags, prose).
SYMBOL_RE = re.compile(r"`([A-Za-z_][A-Za-z0-9_.]*)`")


def _doc(name: str) -> str:
    path = DOCS / name
    assert path.exists(), f"missing {path}"
    return path.read_text()


def _references(text: str):
    return [(m.group(1), int(m.group(2)) if m.group(2) else None)
            for m in PATH_RE.finditer(text)]


@pytest.mark.parametrize("doc", ["PAPER_MAP.md", "ARCHITECTURE.md",
                                 "BENCHMARKS.md"])
def test_referenced_paths_exist(doc):
    refs = _references(_doc(doc))
    assert refs, f"{doc} references no paths — anchor extraction broken?"
    missing = [p for p, _ in refs if not (ROOT / p).exists()]
    assert not missing, f"{doc} references nonexistent paths: {missing}"


def test_line_anchors_are_in_range():
    """`file:line` anchors must not point past the end of the file (they may
    drift a little with edits; pointing beyond EOF means real rot)."""
    bad = []
    for p, line in _references(_doc("PAPER_MAP.md")):
        if line is None:
            continue
        n_lines = len((ROOT / p).read_text().splitlines())
        if line > n_lines:
            bad.append(f"{p}:{line} (file has {n_lines} lines)")
    assert not bad, f"anchors beyond EOF: {bad}"


def test_table_symbols_exist_in_referenced_files():
    """Each PAPER_MAP table cell that anchors file(s) may also name symbols;
    every symbol must appear in at least one of that cell's files (for
    dotted names, the final attribute)."""
    bad = []
    for row in _doc("PAPER_MAP.md").splitlines():
        if not row.strip().startswith("|"):
            continue
        for cell in row.split("|"):
            paths = [p for p, _ in _references(cell)
                     if p.endswith(".py") and (ROOT / p).exists()]
            if not paths:
                continue
            texts = [(ROOT / p).read_text() for p in paths]
            path_tokens = {tok for p in paths for tok in p.split("/")}
            for sym in SYMBOL_RE.findall(cell):
                if sym in path_tokens:
                    continue
                needle = sym.rsplit(".", 1)[-1]
                if not any(needle in t for t in texts):
                    bad.append(f"{sym} not found in {paths}")
    assert not bad, f"stale symbols in PAPER_MAP.md: {bad}"


# --- BENCHMARKS.md <-> benchmark suite lockstep ----------------------------

FLAG_RE = re.compile(r"--[a-z][a-z-]+")
RESULT_RE = re.compile(r"`(?:benchmarks/results/)?([\w-]+\.(?:json|md))`")


def _benchmark_sections():
    """(heading, body) per '## ' section of BENCHMARKS.md."""
    parts = re.split(r"^## ", _doc("BENCHMARKS.md"), flags=re.MULTILINE)
    return [(p.splitlines()[0], p) for p in parts]


def test_benchmarks_doc_covers_every_module():
    """Every benchmarks/bench_*.py module is referenced (one section each —
    a new bench lands with its documentation)."""
    text = _doc("BENCHMARKS.md")
    modules = sorted(p.name for p in (ROOT / "benchmarks").glob("bench_*.py"))
    assert modules, "no bench modules found — glob broken?"
    missing = [m for m in modules if f"benchmarks/{m}" not in text]
    assert not missing, f"BENCHMARKS.md does not document: {missing}"


def test_benchmarks_doc_runner_names_exist():
    """Every `--only` name in the doc's table is a key the orchestrator
    accepts (BENCHES in benchmarks/run.py)."""
    run_src = (ROOT / "benchmarks" / "run.py").read_text()
    benches = set(re.findall(r'^\s+"([\w-]+)":', run_src, flags=re.MULTILINE))
    table_names = re.findall(r"^\| `([\w-]+)` \|", _doc("BENCHMARKS.md"),
                             flags=re.MULTILINE)
    assert table_names, "BENCHMARKS.md lost its runner-name table"
    unknown = [n for n in table_names if n not in benches]
    assert not unknown, f"BENCHMARKS.md names unknown benchmarks: {unknown}"
    undocumented = [b for b in benches if b not in table_names]
    assert not undocumented, f"benchmarks missing from the table: {undocumented}"


def test_benchmarks_doc_result_files_match_writers():
    """Each section's result-file names must be produced by the module(s)
    that section references (the write_result name / literal filename
    appears in the module source) — stale filenames rot silently otherwise."""
    bad = []
    for heading, body in _benchmark_sections():
        mods = [p for p, _ in _references(body)
                if p.startswith("benchmarks/") and p.endswith(".py")]
        if not mods:
            continue
        sources = "\n".join((ROOT / p).read_text() for p in mods
                            if (ROOT / p).exists())
        for fname in RESULT_RE.findall(body):
            stem = fname.rsplit(".", 1)[0]
            if stem not in sources:
                bad.append(f"{fname} (section {heading!r}) not written by {mods}")
    assert not bad, f"BENCHMARKS.md references result files nobody writes: {bad}"


def test_benchmarks_doc_flags_exist_in_code():
    """Every --flag the doc mentions is a real argparse option somewhere in
    the benchmark orchestrator or the launch CLIs."""
    accepted = "\n".join(
        p.read_text() for p in
        list((ROOT / "benchmarks").glob("*.py"))
        + list((ROOT / "src/repro/launch").glob("*.py")))
    missing = [f for f in set(FLAG_RE.findall(_doc("BENCHMARKS.md")))
               if f'"{f}"' not in accepted]
    assert not missing, f"BENCHMARKS.md mentions unknown flags: {missing}"


def test_required_paper_coverage():
    """The acceptance floor: Eq. 10 generator, Eq. 12 assessor, negative
    sampling, Eq. 16 aggregation, and Sec. III-E load balancing are mapped."""
    text = _doc("PAPER_MAP.md")
    for needle in ("Eq. 10", "Eq. 12", "Eq. 16", "Sec. III-E"):
        assert needle in text, f"PAPER_MAP.md lost its {needle} row"
    assert re.search(r"negative[- ]sampl", text, re.IGNORECASE), \
        "PAPER_MAP.md lost its negative-sampling rows"
    assert "spreadfgl_gossip" in text, \
        "PAPER_MAP.md lost the gossip method row"
    assert "spreadfgl_async" in text, \
        "PAPER_MAP.md lost the async aggregation row"
    assert "AsyncAggregator" in text, \
        "PAPER_MAP.md lost the FedBuff-style aggregation row"
