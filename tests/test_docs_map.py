"""docs/ stays truthful: every path referenced from PAPER_MAP.md and
ARCHITECTURE.md exists, `file:line` anchors point inside their file, and
every symbol a PAPER_MAP table row names still appears in the file(s) that
row references. This is the CI docs job (see .github/workflows/ci.yml)."""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"

# `path` or `path:line` references inside backticks.
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs)/[\w./-]+\.(?:py|md|json|yml))"
    r"(?::(\d+))?`")
# Identifier-ish backticked tokens (symbols, possibly dotted); excludes
# anything with '/', '-', or spaces (paths, CLI flags, prose).
SYMBOL_RE = re.compile(r"`([A-Za-z_][A-Za-z0-9_.]*)`")


def _doc(name: str) -> str:
    path = DOCS / name
    assert path.exists(), f"missing {path}"
    return path.read_text()


def _references(text: str):
    return [(m.group(1), int(m.group(2)) if m.group(2) else None)
            for m in PATH_RE.finditer(text)]


@pytest.mark.parametrize("doc", ["PAPER_MAP.md", "ARCHITECTURE.md"])
def test_referenced_paths_exist(doc):
    refs = _references(_doc(doc))
    assert refs, f"{doc} references no paths — anchor extraction broken?"
    missing = [p for p, _ in refs if not (ROOT / p).exists()]
    assert not missing, f"{doc} references nonexistent paths: {missing}"


def test_line_anchors_are_in_range():
    """`file:line` anchors must not point past the end of the file (they may
    drift a little with edits; pointing beyond EOF means real rot)."""
    bad = []
    for p, line in _references(_doc("PAPER_MAP.md")):
        if line is None:
            continue
        n_lines = len((ROOT / p).read_text().splitlines())
        if line > n_lines:
            bad.append(f"{p}:{line} (file has {n_lines} lines)")
    assert not bad, f"anchors beyond EOF: {bad}"


def test_table_symbols_exist_in_referenced_files():
    """Each PAPER_MAP table cell that anchors file(s) may also name symbols;
    every symbol must appear in at least one of that cell's files (for
    dotted names, the final attribute)."""
    bad = []
    for row in _doc("PAPER_MAP.md").splitlines():
        if not row.strip().startswith("|"):
            continue
        for cell in row.split("|"):
            paths = [p for p, _ in _references(cell)
                     if p.endswith(".py") and (ROOT / p).exists()]
            if not paths:
                continue
            texts = [(ROOT / p).read_text() for p in paths]
            path_tokens = {tok for p in paths for tok in p.split("/")}
            for sym in SYMBOL_RE.findall(cell):
                if sym in path_tokens:
                    continue
                needle = sym.rsplit(".", 1)[-1]
                if not any(needle in t for t in texts):
                    bad.append(f"{sym} not found in {paths}")
    assert not bad, f"stale symbols in PAPER_MAP.md: {bad}"


def test_required_paper_coverage():
    """The acceptance floor: Eq. 10 generator, Eq. 12 assessor, negative
    sampling, Eq. 16 aggregation, and Sec. III-E load balancing are mapped."""
    text = _doc("PAPER_MAP.md")
    for needle in ("Eq. 10", "Eq. 12", "Eq. 16", "Sec. III-E"):
        assert needle in text, f"PAPER_MAP.md lost its {needle} row"
    assert re.search(r"negative[- ]sampl", text, re.IGNORECASE), \
        "PAPER_MAP.md lost its negative-sampling rows"
    assert "spreadfgl_gossip" in text, \
        "PAPER_MAP.md lost the gossip method row"
