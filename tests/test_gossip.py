"""Gossip aggregation semantics (Sec. III-E decentralized edge training).

Pins the equivalences the ``spreadfgl_gossip`` composition rests on:

- ``GossipAggregator(ring, every_k=1)`` == ``NeighborAggregator`` on a ring
  adjacency (the ISSUE's allclose parity regression), for the raw
  aggregator AND full fixed-seed training histories.
- Skip rounds (round-phase not on the exchange schedule) == per-server
  FedAvg with no cross-server mixing.
- A gossip exchange preserves the server-mean of parameters (the
  doubly-stochastic property the Fig. 8/9 convergence argument needs) —
  under ``shard_map`` on a real multi-device edge mesh (subprocess).
- Save/resume mid-gossip-interval restores the round-phase: fit(6) ==
  fit(3) + checkpoint round-trip + fit(3) with ``every_k=2``.
"""
import dataclasses
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io
from repro.core import gossip, registry
from repro.core import strategies as S
from repro.core.partition import ring_adjacency
from repro.core.spreadfgl import make_spreadfgl, make_spreadfgl_gossip
from repro.core.types import FGLConfig


def stacked_params(key, m):
    """A [M]-stacked classifier-like pytree with distinct per-client values."""
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 5, 3)),
            "b": jax.random.normal(k2, (m, 3))}


@pytest.fixture(scope="module")
def small(small_batch):
    # Overrides the session `small` (conftest.py): same shared batch, but
    # K=2 so the gossip round-phase and imputation schedule interleave.
    cfg = FGLConfig(hidden_dim=16, local_rounds=2, imputation_interval=2,
                    top_k_links=3, aug_max=8)
    return small_batch, cfg


class TestAggregatorParity:
    @pytest.mark.parametrize("n,m_per", [(2, 2), (4, 2), (8, 1)])
    def test_k1_ring_matches_neighbor_aggregator(self, n, m_per):
        """The pinned regression: GossipAggregator(ring, every_k=1) ==
        NeighborAggregator on a ring adjacency."""
        params = stacked_params(jax.random.key(0), n * m_per)
        adj = jnp.asarray(ring_adjacency(n))
        dense = S.NeighborAggregator().aggregate(
            params, adj=adj, num_servers=n, m_per=m_per)
        gossiped = S.GossipAggregator(topology="ring", every_k=1).aggregate(
            params, adj=adj, num_servers=n, m_per=m_per)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(gossiped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_k1_adjacency_matches_neighbor_aggregator(self):
        """The star/custom-adjacency variant reproduces Eq. 16 for ANY a_rj."""
        n, m_per = 4, 2
        params = stacked_params(jax.random.key(1), n * m_per)
        adj = jnp.asarray(np.array([[1, 1, 0, 1],
                                    [1, 1, 1, 0],
                                    [0, 1, 1, 1],
                                    [1, 0, 1, 1]], np.float32))
        dense = S.NeighborAggregator().aggregate(
            params, adj=adj, num_servers=n, m_per=m_per)
        gossiped = S.GossipAggregator(topology="adjacency", every_k=1).aggregate(
            params, adj=adj, num_servers=n, m_per=m_per)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(gossiped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_skip_round_is_per_server_fedavg(self):
        """Off-schedule rounds do only within-server averaging."""
        n, m_per = 4, 2
        params = stacked_params(jax.random.key(2), n * m_per)
        adj = jnp.asarray(ring_adjacency(n))
        agg = S.GossipAggregator(topology="ring", every_k=4)
        fedavg = S.FedAvgAggregator().aggregate(
            params, adj=adj, num_servers=n, m_per=m_per)
        for phase in (0, 1, 2):    # exchange happens only at phase 3
            skipped = agg.aggregate(params, adj=adj, num_servers=n,
                                    m_per=m_per, round=phase)
            for a, b in zip(jax.tree.leaves(fedavg), jax.tree.leaves(skipped)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6)
        exchanged = agg.aggregate(params, adj=adj, num_servers=n,
                                  m_per=m_per, round=3)
        assert not np.allclose(np.asarray(exchanged["w"]),
                               np.asarray(fedavg["w"]), rtol=1e-6)

    def test_exchange_preserves_server_mean(self):
        """Ring gossip is doubly stochastic: the mean server model is
        invariant (the convergence argument of Fig. 8/9)."""
        n, m_per = 8, 1
        params = stacked_params(jax.random.key(3), n * m_per)
        agg = S.GossipAggregator(topology="ring", every_k=1)
        out = agg.aggregate(params, adj=jnp.asarray(ring_adjacency(n)),
                            num_servers=n, m_per=m_per)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a).mean(0),
                                       np.asarray(b).mean(0), rtol=1e-5)

    def test_block_ring_matches_per_server_ring(self):
        """block_ring_gossip on the host axis == ring neighbor average."""
        n = 5
        w = {"w": jax.random.normal(jax.random.key(4), (n, 3))}
        out = gossip.block_ring_gossip(w)["w"]
        for j in range(n):
            want = (w["w"][j] + w["w"][(j - 1) % n] + w["w"][(j + 1) % n]) / 3
            np.testing.assert_allclose(np.asarray(out[j]), np.asarray(want),
                                       rtol=1e-6)

    def test_validation(self):
        with pytest.raises(ValueError, match="topology"):
            S.GossipAggregator(topology="mesh")
        with pytest.raises(ValueError, match="every_k"):
            S.GossipAggregator(every_k=0)


class TestRingSingleSource:
    """Ring structure has one source: ``partition.ring_adjacency``.

    ``strategies.RingTopology`` consumes that matrix directly; the implicit
    left/right collective_permute schedule of ``gossip.block_ring_gossip``
    must realize the SAME adjacency — this cross-consistency check is what
    lets the repo keep a matrix-free ring kernel without a second ring
    definition drifting from the first (see both docstrings).
    """

    @pytest.mark.parametrize("n", [3, 5, 8])
    def test_block_ring_gossip_equals_ring_adjacency_mix(self, n):
        w = {"w": jax.random.normal(jax.random.key(2), (n, 4, 3))}
        via_permute = gossip.block_ring_gossip(w)
        via_matrix = gossip.adjacency_gossip(w, jnp.asarray(ring_adjacency(n)))
        np.testing.assert_allclose(np.asarray(via_permute["w"]),
                                   np.asarray(via_matrix["w"]), rtol=1e-6)

    def test_ring_topology_layout_uses_ring_adjacency(self):
        lay = S.RingTopology(num_servers=4).build(8)
        np.testing.assert_array_equal(lay.adjacency, ring_adjacency(4))


class TestEngineParity:
    def test_k1_history_matches_dense_spreadfgl(self, small):
        """Full training: spreadfgl_gossip(K=1) == SpreadFGL round for round."""
        batch, cfg = small
        _, dense = make_spreadfgl(cfg, batch, num_servers=2).fit(
            jax.random.key(0), batch, rounds=4)
        _, gossiped = make_spreadfgl_gossip(cfg, batch, num_servers=2,
                                            gossip_every=1).fit(
            jax.random.key(0), batch, rounds=4)
        for k in ("loss", "acc", "f1"):
            np.testing.assert_allclose(gossiped[k], dense[k], rtol=1e-4,
                                       atol=1e-6, err_msg=f"history[{k!r}]")

    def test_registry_builds_gossip_method(self, small):
        batch, cfg = small
        assert "spreadfgl_gossip" in registry.names()
        tr = registry.build("spreadfgl_gossip", cfg, batch, num_servers=2,
                            gossip_every=3)
        assert isinstance(tr.aggregator, S.GossipAggregator)
        assert tr.aggregator.every_k == 3
        assert tr._agg_period == 3

    def test_gossip_every_defaults_to_cfg(self, small):
        batch, cfg = small
        cfg = dataclasses.replace(cfg, gossip_every=5)
        tr = registry.build("spreadfgl_gossip", cfg, batch, num_servers=2)
        assert tr.aggregator.every_k == 5

    def test_k_gt_1_differs_from_dense(self, small):
        """The schedule is real: K=2 produces a different round-1 state."""
        batch, cfg = small
        _, dense = make_spreadfgl(cfg, batch, num_servers=2).fit(
            jax.random.key(0), batch, rounds=2)
        _, gossiped = make_spreadfgl_gossip(cfg, batch, num_servers=2,
                                            gossip_every=2).fit(
            jax.random.key(0), batch, rounds=2)
        assert not np.allclose(gossiped["loss"], dense["loss"], rtol=1e-6)


class TestResumeMidInterval:
    def test_resume_restores_gossip_phase(self, small):
        """fit 6 == fit 3 + save/load + fit 3 with every_k=2: the resumed
        run re-enters the exchange schedule at phase round%K (round 3 is an
        exchange round — only hit if the phase survives the checkpoint)."""
        batch, cfg = small
        tr = make_spreadfgl_gossip(cfg, batch, num_servers=2, gossip_every=2)
        _, full = tr.fit(jax.random.key(0), batch, rounds=6)

        state, first = tr.fit(jax.random.key(0), batch, rounds=3)
        path = os.path.join(tempfile.mkdtemp(), "gossip_resume.npz")
        io.save(path, state)
        restored = io.restore(path, tr.init(jax.random.key(0), batch))
        assert restored.round == 3
        state2, second = tr.fit(state=restored, rounds=3)

        assert first["round"] + second["round"] == full["round"]
        for k in ("loss", "acc", "f1"):
            np.testing.assert_allclose(first[k] + second[k], full[k],
                                       atol=1e-6, err_msg=f"history[{k!r}]")
        assert state2.round == 6


@pytest.mark.slow
def test_gossip_exchange_crosses_edge_mesh_subprocess():
    """GossipAggregator under shard_map on a 4-device edge mesh: the
    exchange matches the mesh-free path and preserves the server mean —
    aggregation bytes genuinely cross the (emulated) device boundary."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import strategies as S
        from repro.core.partition import ring_adjacency

        n, m_per = 4, 2
        key = jax.random.key(0)
        params = {"w": jax.random.normal(key, (n * m_per, 5, 3))}
        adj = jnp.asarray(ring_adjacency(n))
        mesh = Mesh(jax.devices()[:4], ("edge",))
        meshed = S.GossipAggregator(topology="ring", every_k=1, mesh=mesh)
        hosted = S.GossipAggregator(topology="ring", every_k=1)
        a = meshed.aggregate(params, adj=adj, num_servers=n, m_per=m_per)
        b = hosted.aggregate(params, adj=adj, num_servers=n, m_per=m_per)
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a["w"]).mean(0),
                                   np.asarray(params["w"]).mean(0), rtol=1e-5)
        # Block-sharded: 4 servers on a 2-device mesh (2 servers per shard).
        mesh2 = Mesh(jax.devices()[:2], ("edge",))
        blocked = S.GossipAggregator(topology="ring", every_k=1, mesh=mesh2)
        c = blocked.aggregate(params, adj=adj, num_servers=n, m_per=m_per)
        np.testing.assert_allclose(np.asarray(c["w"]), np.asarray(b["w"]),
                                   rtol=1e-6)
        print("GOSSIP-MESH-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "GOSSIP-MESH-OK" in out.stdout, out.stderr[-2000:]
