"""kernel_impl dispatch through the FGL hot loop.

The single ``FGLConfig.kernel_impl`` knob must (a) actually reach both hot
paths — classifier aggregation and the imputation round's fused similarity
top-k — and (b) be numerically interchangeable: one full SpreadFGL imputation
round under ``pallas_interpret`` matches ``reference`` on the raw link
proposals (scores, idx, x̄) and on the fixed batch, including shapes that are
not multiples of the kernel block sizes. Also pins the aug-slot target
bugfix: imputed (synthetic) nodes are never chosen as link targets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imputation, registry
from repro.core.fedgl import FGLTrainer
from repro.core.spreadfgl import make_fedgl, make_spreadfgl

# `small` comes from the session-scoped fixture in tests/conftest.py; its
# n_flat = M_per * n_pad is NOT a multiple of the kernel block sizes, which
# exercises the ops.py padding path in situ.


def _round_outputs(tr, state):
    """(scores, idx, x_bar) per server plus the fixed state, via the real
    strategy path (SpreadImputation.server_outputs + impute)."""
    (_, _, _, _, scores, idx, x_bar), _ = tr.imputation.server_outputs(tr, state)
    return scores, idx, x_bar, tr._impute_fn(state)


class TestImputationRoundParity:
    @pytest.mark.parametrize("build,kw", [
        (make_spreadfgl, {"num_servers": 2}),   # n_flat = 2 * n_pad per server
        (make_fedgl, {}),                       # star: n_flat = 4 * n_pad
    ])
    def test_full_round_interpret_matches_reference(self, small, build, kw):
        batch, cfg = small
        tr_ref = build(cfg, batch, **kw)
        tr_pls = build(dataclasses.replace(cfg, kernel_impl="pallas_interpret"),
                       batch, **kw)
        state = tr_ref.init(jax.random.key(0), batch)
        s_ref, i_ref, x_ref, out_ref = _round_outputs(tr_ref, state)
        s_pls, i_pls, x_pls, out_pls = _round_outputs(tr_pls, state)

        np.testing.assert_allclose(np.asarray(s_pls), np.asarray(s_ref),
                                   atol=1e-4, err_msg="link scores diverged")
        np.testing.assert_array_equal(np.asarray(i_pls), np.asarray(i_ref),
                                      err_msg="link targets diverged")
        np.testing.assert_allclose(np.asarray(x_pls), np.asarray(x_ref),
                                   atol=1e-4, err_msg="imputed X̅ diverged")
        for name in ("x", "adj", "node_mask"):
            np.testing.assert_allclose(
                np.asarray(getattr(out_pls.batch, name), np.float32),
                np.asarray(getattr(out_ref.batch, name), np.float32),
                atol=1e-4, err_msg=f"fixed batch .{name} diverged")

    def test_second_round_parity_after_graph_fixing(self, small):
        """Parity survives a second round on the already-fixed batch."""
        batch, cfg = small
        tr_ref = make_spreadfgl(cfg, batch, num_servers=2)
        tr_pls = make_spreadfgl(
            dataclasses.replace(cfg, kernel_impl="pallas_interpret"),
            batch, num_servers=2)
        state = tr_ref._impute_fn(tr_ref.init(jax.random.key(0), batch))
        _, i_ref, _, out_ref = _round_outputs(tr_ref, state)
        _, i_pls, _, out_pls = _round_outputs(tr_pls, state)
        np.testing.assert_array_equal(np.asarray(i_pls), np.asarray(i_ref))
        np.testing.assert_allclose(np.asarray(out_pls.batch.x, np.float32),
                                   np.asarray(out_ref.batch.x, np.float32),
                                   atol=1e-4)


class TestAugSlotTargets:
    @pytest.mark.parametrize("kernel_impl", ["reference", "pallas_interpret"])
    def test_no_link_targets_aug_slots_across_rounds(self, small, kernel_impl):
        """Two consecutive fixing rounds never link to synthetic nodes.

        After round one the patcher sets node_mask=1 on the aug slots it
        filled; without the local-slot target restriction, round two's
        similarity top-k could select those synthetic nodes as cross-subgraph
        targets and re-impute features of imputed slots.
        """
        batch, cfg = small
        tr = make_spreadfgl(dataclasses.replace(cfg, kernel_impl=kernel_impl),
                            batch, num_servers=2)
        n_pad, n_local = batch.n_pad, batch.n_local_max
        state = tr.init(jax.random.key(0), batch)
        for rnd in range(2):
            (_, _, _, _, _, idx, _), _ = tr.imputation.server_outputs(tr, state)
            chosen = np.asarray(idx)
            chosen = chosen[chosen >= 0]        # server-local flat slots
            assert (chosen % n_pad < n_local).all(), \
                f"round {rnd}: aug slot chosen as link target"
            state = tr._impute_fn(state)
            # round 1 precondition: the patcher did fill aug slots
            assert float(jnp.sum(state.batch.node_mask[:, n_local:])) > 0

    def test_aug_rows_do_not_source_links(self, small):
        """Aug-slot rows are invalid sources: their idx rows stay -1 after
        the patcher marked them real (flat_mask covers them, target_mask and
        fix_graphs' source filter keep them out)."""
        batch, cfg = small
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        state = tr._impute_fn(tr.init(jax.random.key(0), batch))
        emb = tr._embeddings(state.params, state.batch)
        n_pad = state.batch.n_pad
        h_flat, flat_mask = imputation.fuse_embeddings(
            emb[:tr.m_per], state.batch.node_mask[:tr.m_per])
        tmask = flat_mask * imputation.local_slot_mask(tr.m_per, n_pad,
                                                       tr.n_local)
        assert float(jnp.sum(flat_mask) - jnp.sum(tmask)) > 0  # aug slots real


class TestKernelImplKnob:
    def test_unknown_impl_rejected_at_construction(self, small):
        batch, cfg = small
        with pytest.raises(ValueError, match="kernel_impl"):
            make_fedgl(dataclasses.replace(cfg, kernel_impl="triton"), batch)

    def test_constructor_override_wins_over_cfg(self, small):
        batch, cfg = small
        tr = make_fedgl(cfg, batch, kernel_impl="pallas_interpret")
        assert tr.kernel_impl == "pallas_interpret"
        assert tr.cfg.kernel_impl == "pallas_interpret"

    def test_registry_passes_kernel_impl(self, small):
        batch, cfg = small
        for name in ("FedGL", "local", "fedavg_fusion"):
            tr = registry.build(name, cfg, batch,
                                kernel_impl="pallas_interpret")
            assert isinstance(tr, FGLTrainer)
            assert tr.kernel_impl == "pallas_interpret"

    def test_training_step_runs_under_interpret(self, small):
        """A full step() (local training + impute + aggregate + eval) runs
        end-to-end through the Pallas kernels in interpret mode."""
        batch, cfg = small
        tr = make_spreadfgl(
            dataclasses.replace(cfg, kernel_impl="pallas_interpret",
                                local_rounds=1),
            batch, num_servers=2)
        state = tr.init(jax.random.key(0), batch)
        state, m = tr.step(state)
        assert np.isfinite(float(m["loss"]))
        assert state.round == 1
