"""Property tests for the pluggable partitioning subsystem.

Every Partitioner strategy must honor the same ``assign`` contract —
every node on exactly one client, no empty client, deterministic per seed —
and the Dirichlet strategy's label skew must be monotone in alpha
(measured as per-client label entropy). The default strategy must stay
bit-compatible with the pre-protocol ``partition_graph`` (the absolute pin
is the fixed-seed goldens in ``tests/test_strategy_api.py``; here we pin
``partitioner=None`` == ``"label_prop"``).
"""
import numpy as np
import pytest

from repro.core import partition as P

ALL_PARTITIONERS = sorted(P.PARTITIONERS)


@pytest.fixture(scope="module")
def graph(sbm_graph_small):
    # The shared session graph (tests/conftest.py) — same fixed-seed build.
    return sbm_graph_small


class TestAssignContract:
    """The invariants every strategy promises, across strategies and seeds."""

    @pytest.mark.parametrize("name", ALL_PARTITIONERS)
    @pytest.mark.parametrize("num_clients", [3, 5])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_every_node_assigned_exactly_once(self, graph, name, num_clients,
                                              seed):
        assign = P.make_partitioner(name).assign(graph, num_clients, seed=seed)
        assert assign.shape == (graph.num_nodes,)
        assert assign.dtype == np.int32
        assert assign.min() >= 0 and assign.max() < num_clients
        # non-empty clients: the engine's reshape requires every client real
        assert len(np.unique(assign)) == num_clients

    @pytest.mark.parametrize("name", ALL_PARTITIONERS)
    def test_deterministic_per_seed(self, graph, name):
        part = P.make_partitioner(name, alpha=0.5)
        a = part.assign(graph, 4, seed=7)
        b = part.assign(graph, 4, seed=7)
        np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("name", ["dirichlet", "random"])
    def test_seed_actually_varies_random_strategies(self, graph, name):
        part = P.make_partitioner(name)
        a = part.assign(graph, 4, seed=0)
        b = part.assign(graph, 4, seed=1)
        assert np.any(a != b)

    @pytest.mark.parametrize("name", ALL_PARTITIONERS)
    def test_partition_graph_batch_covers_graph(self, graph, name):
        """The dispatcher materializes every strategy's assign identically:
        the padded batch holds each global node exactly once, and only
        intra-client edges survive."""
        batch, assign = P.partition_graph(graph, 4, aug_max=8, seed=0,
                                          partitioner=name)
        ids = np.asarray(batch.global_id)
        real = ids[ids >= 0]
        assert len(real) == graph.num_nodes
        assert len(np.unique(real)) == graph.num_nodes
        for ci in range(batch.num_clients):
            rows, cols = np.nonzero(np.asarray(batch.adj[ci]))
            mask = np.asarray(batch.node_mask[ci])
            assert mask[rows].all() and mask[cols].all()


class TestDirichletSkew:
    def test_entropy_monotone_in_alpha(self, graph):
        """Per-client label entropy orders with the concentration: near-IID
        (alpha=100) >= moderate (1) >= extreme skew (0.1), averaged over
        seeds so one lucky draw can't flip the ordering."""
        def mean_ent(alpha):
            ents = []
            for seed in (0, 1, 2):
                a = P.DirichletPartitioner(alpha=alpha).assign(graph, 5,
                                                               seed=seed)
                ents.append(P.label_skew_entropy(a, graph.y, 5).mean())
            return float(np.mean(ents))

        e100, e1, e01 = mean_ent(100.0), mean_ent(1.0), mean_ent(0.1)
        assert e100 > e1 > e01, (e100, e1, e01)

    def test_rejects_nonpositive_alpha(self, graph):
        with pytest.raises(ValueError, match="alpha"):
            P.DirichletPartitioner(alpha=0.0).assign(graph, 4)


class TestDegreeSkew:
    def test_client_degree_profiles_ordered(self, graph):
        """Client 0 owns the sparsest slice, client M-1 the hubs."""
        assign = P.DegreeSkewPartitioner().assign(graph, 4, seed=0)
        deg = np.zeros(graph.num_nodes)
        np.add.at(deg, np.asarray(graph.senders), 1.0)
        np.add.at(deg, np.asarray(graph.receivers), 1.0)
        means = [deg[assign == ci].mean() for ci in range(4)]
        assert means == sorted(means)
        sizes = np.bincount(assign, minlength=4)
        assert sizes.max() - sizes.min() <= 1  # near-equal split


class TestRandomEdgeCut:
    def test_cuts_most_edges(self, graph):
        """Random assignment is the worst case: it must cut more links than
        the community-aware default on the same graph."""
        a_rand = P.RandomEdgeCutPartitioner().assign(graph, 4, seed=0)
        a_comm = P.LabelPropagationPartitioner().assign(graph, 4, seed=0)
        assert (P.count_missing_links(graph, a_rand)
                > P.count_missing_links(graph, a_comm))


class TestDispatcher:
    def test_default_is_label_prop_bitwise(self, graph):
        """partitioner=None, the "label_prop" name, and an explicit instance
        all produce the identical batch (the fixed-seed goldens of
        tests/test_strategy_api.py pin this behavior to the pre-protocol
        partition_graph)."""
        b0, a0 = P.partition_graph(graph, 4, aug_max=8, seed=0)
        b1, a1 = P.partition_graph(graph, 4, aug_max=8, seed=0,
                                   partitioner="label_prop")
        b2, a2 = P.partition_graph(graph, 4, aug_max=8, seed=0,
                                   partitioner=P.LabelPropagationPartitioner())
        np.testing.assert_array_equal(a0, a1)
        np.testing.assert_array_equal(a0, a2)
        for k in ("x", "adj", "y", "node_mask", "train_mask", "test_mask",
                  "global_id"):
            np.testing.assert_array_equal(np.asarray(getattr(b0, k)),
                                          np.asarray(getattr(b1, k)), err_msg=k)
            np.testing.assert_array_equal(np.asarray(getattr(b0, k)),
                                          np.asarray(getattr(b2, k)), err_msg=k)

    def test_make_partitioner_unknown_name(self):
        with pytest.raises(KeyError, match="label_prop"):
            P.make_partitioner("louvain")

    def test_make_partitioner_drops_foreign_kwargs(self):
        """Callers may pass alpha unconditionally; non-Dirichlet strategies
        simply ignore it."""
        part = P.make_partitioner("degree", alpha=0.5)
        assert isinstance(part, P.DegreeSkewPartitioner)
        part = P.make_partitioner("dirichlet", alpha=0.5)
        assert part.alpha == 0.5

    def test_all_strategies_satisfy_protocol(self):
        for name in ALL_PARTITIONERS:
            assert isinstance(P.make_partitioner(name), P.Partitioner)
