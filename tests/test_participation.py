"""Partial client participation: static-shape masks, mask-weighted
aggregation in all four Aggregators, and the engine threading.

The contract: rho = 1 takes the exact unmasked code paths (fixed-seed
histories bit-identical to pre-participation runs — the absolute pin being
the goldens in ``tests/test_strategy_api.py``, which run at the default
``participation=1.0``); rho < 1 samples a static [M] mask per round from a
key stream that is a pure function of (cfg.seed, round), so checkpoints
resume the participation schedule exactly.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io
from repro.core import registry
from repro.core import strategies as S
from repro.core.fedgl import FGLTrainer
from repro.core.partition import ring_adjacency

# `small` comes from the session-scoped fixture in tests/conftest.py.


def _stack_params(key, m, shape=(3, 2)):
    """A toy [M, ...] stacked-client pytree with distinct per-client values."""
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m,) + shape),
            "b": jax.random.normal(k2, (m, shape[-1]))}


class TestParticipationMask:
    def test_static_shape_and_exact_count(self):
        for rho, want in [(0.5, 3), (0.25, 2), (0.1, 1), (1.0, 6)]:
            mask = S.participation_mask(jax.random.key(0), 6, rho)
            assert mask.shape == (6,) and mask.dtype == jnp.float32
            assert float(mask.sum()) == want, rho
            assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}

    def test_deterministic_per_key_and_varies_across_keys(self):
        base = jax.random.key(3)
        a = S.participation_mask(jax.random.fold_in(base, 0), 8, 0.5)
        b = S.participation_mask(jax.random.fold_in(base, 0), 8, 0.5)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        rounds = [S.participation_mask(jax.random.fold_in(base, t), 8, 0.5)
                  for t in range(6)]
        assert any(np.any(np.asarray(rounds[0]) != np.asarray(r))
                   for r in rounds[1:])

    def test_rejects_out_of_range_rho(self):
        for rho in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError, match="participation"):
                S.participation_mask(jax.random.key(0), 6, rho)


class TestMaskedAggregators:
    """mask=None vs all-ones vs genuinely partial, per aggregator."""

    N, M_PER = 2, 3

    def _params(self):
        return _stack_params(jax.random.key(1), self.N * self.M_PER)

    def _kw(self, adj=None):
        return dict(adj=adj if adj is not None
                    else jnp.ones((self.N, self.N), jnp.float32),
                    num_servers=self.N, m_per=self.M_PER)

    @pytest.mark.parametrize("agg", [
        S.FedAvgAggregator(), S.NeighborAggregator(),
        S.GossipAggregator(topology="adjacency", every_k=1)])
    def test_all_ones_mask_matches_unmasked(self, agg):
        """Full-participation mask reproduces the mask=None path bitwise:
        multiplying by 1.0 and dividing by the same count change nothing."""
        params = self._params()
        ones = jnp.ones((self.N * self.M_PER,), jnp.float32)
        out_none = agg.aggregate(params, **self._kw())
        out_ones = agg.aggregate(params, mask=ones, **self._kw())
        for a, b in zip(jax.tree.leaves(out_none), jax.tree.leaves(out_ones)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fedavg_weighted_mean_preservation(self):
        """Per-server output is exactly the mean of participating clients."""
        params = self._params()
        mask = jnp.asarray([1, 0, 1, 0, 0, 1], jnp.float32)
        out = S.FedAvgAggregator().aggregate(params, mask=mask, **self._kw())
        w = np.asarray(params["w"])
        np.testing.assert_allclose(np.asarray(out["w"])[0],
                                   (w[0] + w[2]) / 2, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out["w"])[3], w[5], rtol=1e-6)
        # broadcast back to every covered client, participating or not
        np.testing.assert_array_equal(np.asarray(out["w"])[0],
                                      np.asarray(out["w"])[1])

    def test_fedavg_all_out_server_falls_back_to_plain_mean(self):
        params = self._params()
        mask = jnp.asarray([0, 0, 0, 1, 1, 0], jnp.float32)
        out = S.FedAvgAggregator().aggregate(params, mask=mask, **self._kw())
        w = np.asarray(params["w"])
        np.testing.assert_allclose(np.asarray(out["w"])[0],
                                   w[:3].mean(axis=0), rtol=1e-6)

    def test_neighbor_matches_hand_computed_eq16(self):
        """Eq. 16 with M_r replaced by the participating count m-tilde_r."""
        params = self._params()
        adj = jnp.asarray(ring_adjacency(2))  # N=2: all-to-all incl self
        mask = jnp.asarray([1, 1, 0, 1, 0, 0], jnp.float32)
        out = S.NeighborAggregator().aggregate(params, mask=mask,
                                               **self._kw(adj))
        w = np.asarray(params["w"])
        a = np.asarray(adj)
        csum = np.stack([w[0] + w[1], w[3]])          # masked client sums [N]
        counts = np.asarray([2.0, 1.0])
        for j in range(2):
            num = sum(a[r, j] * csum[r] for r in range(2))
            den = sum(a[r, j] * counts[r] for r in range(2))
            np.testing.assert_allclose(np.asarray(out["w"])[j * 3], num / den,
                                       rtol=1e-6)

    def test_identity_ignores_mask(self):
        params = self._params()
        mask = jnp.asarray([1, 0, 0, 0, 0, 0], jnp.float32)
        out = S.IdentityAggregator().aggregate(params, mask=mask, **self._kw())
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gossip_skip_round_equals_masked_fedavg(self):
        """On non-exchange rounds gossip is per-server FedAvg — with a mask,
        per-server *masked* FedAvg."""
        params = self._params()
        mask = jnp.asarray([1, 0, 1, 0, 1, 1], jnp.float32)
        gossip = S.GossipAggregator(topology="adjacency", every_k=4)
        out_g = gossip.aggregate(params, round=0, mask=mask, **self._kw())
        out_f = S.FedAvgAggregator().aggregate(params, mask=mask, **self._kw())
        for a, b in zip(jax.tree.leaves(out_g), jax.tree.leaves(out_f)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_gossip_exchange_mixes_masked_server_means(self):
        """Exchange rounds mix the *masked* per-server means over the
        adjacency — participation gates the edge-client leg only."""
        params = self._params()
        adj = jnp.asarray(ring_adjacency(2))
        mask = jnp.asarray([1, 0, 0, 0, 1, 1], jnp.float32)
        gossip = S.GossipAggregator(topology="adjacency", every_k=1)
        out = gossip.aggregate(params, round=0, mask=mask, **self._kw(adj))
        w = np.asarray(params["w"])
        means = np.stack([w[0], (w[4] + w[5]) / 2])   # masked server means
        want = (means[0] + means[1]) / 2              # N=2 all-to-all mix
        np.testing.assert_allclose(np.asarray(out["w"])[0], want, rtol=1e-6)


class TestEngineThreading:
    def test_rho_one_is_bit_identical_to_default(self, small):
        """participation=1.0 never samples a mask: histories equal the
        default-config run exactly (and therefore the pinned goldens)."""
        batch, cfg = small
        tr_def = registry.build("SpreadFGL", cfg, batch, num_servers=2)
        tr_one = registry.build(
            "SpreadFGL", dataclasses.replace(cfg, participation=1.0),
            batch, num_servers=2)
        _, h_def = tr_def.fit(jax.random.key(0), batch, rounds=3)
        _, h_one = tr_one.fit(jax.random.key(0), batch, rounds=3)
        assert h_def == h_one
        assert tr_one._participation_mask(0) is None

    def test_rho_below_one_changes_training_and_stays_finite(self, small):
        batch, cfg = small
        tr = registry.build("SpreadFGL", cfg, batch, num_servers=2,
                            participation=0.5)
        _, h_full = registry.build("SpreadFGL", cfg, batch, num_servers=2
                                   ).fit(jax.random.key(0), batch, rounds=3)
        _, h_half = tr.fit(jax.random.key(0), batch, rounds=3)
        assert np.isfinite(h_half["loss"]).all()
        assert h_half["acc"] != h_full["acc"]

    def test_mask_is_pure_function_of_round(self, small):
        """Same trainer, same round -> same mask; masks vary across rounds."""
        batch, cfg = small
        tr = registry.build("FedGL", cfg, batch, participation=0.5)
        m0a, m0b = tr._participation_mask(0), tr._participation_mask(0)
        np.testing.assert_array_equal(np.asarray(m0a), np.asarray(m0b))
        masks = [np.asarray(tr._participation_mask(t)) for t in range(8)]
        assert any(np.any(masks[0] != m) for m in masks[1:])
        for m in masks:
            assert m.shape == (batch.num_clients,) and m.sum() == 2

    def test_resume_roundtrip_under_partial_participation(self, small):
        """fit 4 == fit 2 + checkpoint + fit 2 with rho < 1: the mask keys
        off the absolute round, like the imputation and gossip schedules."""
        batch, cfg = small
        cfg = dataclasses.replace(cfg, imputation_interval=2,
                                  participation=0.5)
        tr = registry.build("SpreadFGL", cfg, batch, num_servers=2)
        _, full = tr.fit(jax.random.key(0), batch, rounds=4)
        state, first = tr.fit(jax.random.key(0), batch, rounds=2)
        path = os.path.join(tempfile.mkdtemp(), "part_resume.npz")
        io.save(path, state)
        restored = io.restore(path, tr.init(jax.random.key(0), batch))
        _, second = tr.fit(state=restored, rounds=2)
        for k in ("loss", "acc", "f1"):
            np.testing.assert_allclose(first[k] + second[k], full[k],
                                       atol=1e-6)

    def test_ctor_override_wins_over_cfg(self, small):
        batch, cfg = small
        tr = FGLTrainer(dataclasses.replace(cfg, participation=0.25), batch,
                        participation=0.75)
        assert tr.participation == 0.75
        assert tr.cfg.participation == 0.75

    def test_rejects_out_of_range(self, small):
        batch, cfg = small
        for rho in (0.0, 1.5, -1.0):
            with pytest.raises(ValueError, match="participation"):
                FGLTrainer(cfg, batch, participation=rho)

    @pytest.mark.parametrize("name,kw", [
        ("local", {}), ("fedavg_fusion", {}), ("fedsage_plus", {}),
        ("FedGL", {}), ("spreadfgl_gossip", {"num_servers": 2,
                                             "gossip_every": 2})])
    def test_every_registered_method_trains_under_partial(self, small, name,
                                                          kw):
        batch, cfg = small
        tr = registry.build(name, cfg, batch, participation=0.5, **kw)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=2)
        assert np.isfinite(hist["loss"]).all(), name
