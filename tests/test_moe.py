"""MoE router/dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

KEY = jax.random.key(3)


def _naive_moe(p, x, top_k, act):
    """Oracle: every token runs its top-k experts with normalized weights
    (no capacity limit)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    gates = jax.nn.softmax(x.astype(jnp.float32) @ p["router"], -1)
    topw, topi = jax.lax.top_k(gates, top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for ei in range(e):
        up = x @ p["w_up"][ei]
        if act == "silu":
            up = jax.nn.silu(x @ p["w_gate"][ei]) * up
        else:
            up = jax.nn.gelu(up)
        y = up @ p["w_down"][ei]
        w = jnp.where(topi == ei, topw, 0.0).sum(-1)
        out = out + y.astype(jnp.float32) * w[..., None]
    return out.astype(x.dtype)


@pytest.mark.parametrize("e,k,dff", [(4, 2, 32), (8, 2, 16), (4, 1, 16)])
def test_moe_matches_naive_when_capacity_ample(e, k, dff):
    b, s, d = 2, 16, 24
    p = moe.init_moe(KEY, d, dff, e, "silu", jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, e + k), (b, s, d))
    out, aux = moe.apply_moe(p, x, num_experts=e, top_k=k,
                             capacity_factor=float(e) / k, act="silu")
    expect = _naive_moe(p, x, k, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1, output is damped (tokens dropped)."""
    b, s, d, e, k = 1, 32, 16, 4, 2
    p = moe.init_moe(KEY, d, 32, e, "silu", jnp.float32)
    x = jax.random.normal(KEY, (b, s, d))
    full, _ = moe.apply_moe(p, x, num_experts=e, top_k=k,
                            capacity_factor=2.0, act="silu")
    tight, _ = moe.apply_moe(p, x, num_experts=e, top_k=k,
                             capacity_factor=0.1, act="silu")
    assert float(jnp.sum(jnp.abs(tight))) < float(jnp.sum(jnp.abs(full)))


def test_moe_aux_loss_minimized_when_balanced():
    """Switch aux loss >= 1 with equality iff uniform routing."""
    b, s, d, e = 2, 64, 8, 4
    p = moe.init_moe(KEY, d, 16, e, "silu", jnp.float32)
    # uniform router -> aux == 1
    p = dict(p, router=jnp.zeros((d, e), jnp.float32))
    x = jax.random.normal(KEY, (b, s, d))
    _, aux = moe.apply_moe(p, x, num_experts=e, top_k=1,
                           capacity_factor=4.0, act="silu")
    # top-1 of a uniform softmax is arbitrary but density*gate_mean*E ~ 1
    assert 0.5 < float(aux) < 2.0
    # collapsed router (all tokens -> expert 0) -> aux ~ E.
    # positive inputs so the collapsed column wins for every token
    x_pos = jnp.abs(x) + 0.1
    p2 = dict(p, router=jnp.zeros((d, e)).at[:, 0].set(5.0))
    _, aux2 = moe.apply_moe(p2, x_pos, num_experts=e, top_k=1,
                            capacity_factor=4.0, act="silu")
    assert float(aux2) > float(aux) * 1.5


def test_moe_group_len_invariance_without_drops():
    """Grouping must not change results when capacity is ample."""
    b, s, d, e, k = 2, 32, 12, 4, 2
    p = moe.init_moe(KEY, d, 24, e, "silu", jnp.float32)
    x = jax.random.normal(KEY, (b, s, d))
    o1, _ = moe.apply_moe(p, x, num_experts=e, top_k=k, capacity_factor=2.0,
                          act="silu", group_len=32)
    o2, _ = moe.apply_moe(p, x, num_experts=e, top_k=k, capacity_factor=2.0,
                          act="silu", group_len=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_moe_decode_single_token():
    b, d, e, k = 4, 12, 4, 2
    p = moe.init_moe(KEY, d, 24, e, "silu", jnp.float32)
    x = jax.random.normal(KEY, (b, 1, d))
    out, _ = moe.apply_moe(p, x, num_experts=e, top_k=k, capacity_factor=1.25,
                           act="silu")
    expect = _naive_moe(p, x, k, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)
