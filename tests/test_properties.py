"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import gnn
from repro.kernels import ref
from repro.models import layers as L
from repro.optim.adam import Adam, clip_by_global_norm, global_norm

jax.config.update("jax_enable_x64", False)
_SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(4, 40), d=st.integers(2, 24), seed=st.integers(0, 1000))
@settings(**_SETTINGS)
def test_normalized_adjacency_row_stochastic(n, d, seed):
    key = jax.random.key(seed)
    adj = (jax.random.uniform(key, (n, n)) < 0.3).astype(jnp.float32)
    adj = jnp.maximum(adj, adj.T)
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < 0.8
            ).astype(jnp.float32)
    a = gnn.normalize_adjacency(adj, mask)
    rows = np.asarray(jnp.sum(a, -1))
    assert np.all(rows <= 1.0 + 1e-5)          # row sums in {0} U (0,1]
    deg = np.asarray((adj * (mask[:, None] * mask[None, :])).sum(-1))
    np.testing.assert_allclose(rows[deg > 0], 1.0, atol=1e-5)


@given(s=st.integers(2, 16), d=st.sampled_from([8, 16, 32]),
       theta=st.sampled_from([1e3, 1e4, 1e6]), seed=st.integers(0, 100))
@settings(**_SETTINGS)
def test_rope_preserves_norm(s, d, theta, seed):
    x = jax.random.normal(jax.random.key(seed), (1, 2, s, d))
    out = L.apply_rope(x, jnp.arange(s), theta)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(out), axis=-1),
                               rtol=1e-5)


@given(s=st.integers(1, 12), d=st.sampled_from([8, 32]), seed=st.integers(0, 50))
@settings(**_SETTINGS)
def test_rope_zero_position_identity(s, d, seed):
    x = jax.random.normal(jax.random.key(seed), (1, 1, s, d))
    out = L.apply_rope(x, jnp.zeros((s,), jnp.int32), 1e4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


@given(n=st.integers(1, 20), d=st.integers(2, 32), seed=st.integers(0, 100))
@settings(**_SETTINGS)
def test_rmsnorm_unit_rms(n, d, seed):
    x = 5.0 * jax.random.normal(jax.random.key(seed), (n, d)) + 1.0
    p = L.init_norm("rmsnorm", d, jnp.float32)
    out = np.asarray(L.apply_norm(p, x, "rmsnorm"))
    rms = np.sqrt((out ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


@given(seed=st.integers(0, 200), clip=st.floats(0.1, 10.0))
@settings(**_SETTINGS)
def test_clip_by_global_norm_bound(seed, clip):
    key = jax.random.key(seed)
    tree = {"a": 10 * jax.random.normal(key, (7, 3)),
            "b": [jax.random.normal(jax.random.fold_in(key, 1), (5,))]}
    clipped = clip_by_global_norm(tree, clip)
    assert float(global_norm(clipped)) <= clip * (1 + 1e-4)


@given(seed=st.integers(0, 100), steps=st.integers(1, 5))
@settings(**_SETTINGS)
def test_adam_descends_quadratic(seed, steps):
    """Adam reduces a convex quadratic from any start."""
    opt = Adam(lr=0.1)
    target = jax.random.normal(jax.random.key(seed), (6,))
    p = {"w": jnp.zeros((6,))}
    st_ = opt.init(p)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(p))
    for _ in range(steps * 10):
        g = jax.grad(loss)(p)
        p, st_ = opt.update(g, st_, p)
    assert float(loss(p)) < l0


@given(sq=st.integers(2, 10), skv=st.integers(2, 10), seed=st.integers(0, 50))
@settings(**_SETTINGS)
def test_attention_oracle_rows_are_convex_combinations(sq, skv, seed):
    """Causal attention output lies in the convex hull of V rows."""
    if skv < sq:
        skv = sq
    key = jax.random.key(seed)
    q = jax.random.normal(key, (1, 1, sq, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, skv, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, skv, 8))
    out = np.asarray(ref.flash_attention(q, k, v, causal=True))
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert out.min() >= vmin - 1e-4 and out.max() <= vmax + 1e-4


@given(n=st.integers(6, 48), k=st.integers(1, 5), seed=st.integers(0, 200),
       n_slabs=st.integers(1, 4), perm_seed=st.integers(0, 100))
@settings(**_SETTINGS)
def test_topk_merge_fold_order_invariance_and_lax_tiebreak(n, k, seed,
                                                           n_slabs, perm_seed):
    """Folding candidate slabs in ANY order yields jax.lax.top_k of the full
    row — values bitwise, indices including the smallest-index tie-break.

    Values are quantized to a coarse grid so ties genuinely occur, and a
    random subset is masked to -inf to exercise the (-inf, -1) convention.
    """
    from repro.kernels.sim_topk import topk_merge

    key = jax.random.key(seed)
    vals = jnp.round(jax.random.uniform(key, (n,)) * 4.0) / 4.0  # many ties
    masked = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < 0.25
    vals = jnp.where(masked, -jnp.inf, vals)
    want_v, want_i = jax.lax.top_k(vals, k)

    bounds = sorted(set(
        [0, n] + list(np.random.RandomState(perm_seed).randint(1, n,
                                                               n_slabs))))
    slabs = [(vals[a:b], jnp.arange(a, b, dtype=jnp.int32))
             for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    order = np.random.RandomState(perm_seed + 1).permutation(len(slabs))
    run_v = jnp.full((k,), -jnp.inf)
    run_i = jnp.full((k,), -1, jnp.int32)
    for j in order:
        run_v, run_i = topk_merge(run_v, run_i, *slabs[j])

    np.testing.assert_array_equal(np.asarray(run_v), np.asarray(want_v))
    live = np.asarray(want_v) > -np.inf
    # lax.top_k emits real indices for -inf entries; the merge emits -1.
    np.testing.assert_array_equal(np.asarray(run_i)[live],
                                  np.asarray(want_i)[live])
    np.testing.assert_array_equal(np.asarray(run_i)[~live],
                                  np.full((~live).sum(), -1))


@given(m=st.integers(1, 24), rho=st.floats(0.01, 1.0), seed=st.integers(0, 200))
@settings(**_SETTINGS)
def test_participation_mask_exact_count_and_determinism(m, rho, seed):
    """Exactly ceil(rho*M) participants, 0/1 values, static [M] shape, and
    the same key always reproduces the same mask."""
    import math

    from repro.core import strategies as S

    key = jax.random.key(seed)
    mask = S.participation_mask(key, m, rho)
    assert mask.shape == (m,) and mask.dtype == jnp.float32
    assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}
    assert int(np.asarray(mask).sum()) == max(1, math.ceil(rho * m))
    np.testing.assert_array_equal(
        np.asarray(mask), np.asarray(S.participation_mask(key, m, rho)))


@given(seed=st.integers(0, 500), t=st.integers(0, 50), m=st.integers(1, 32),
       dist=st.sampled_from(["zero", "uniform", "geometric"]),
       max_delay=st.integers(0, 6), drop=st.floats(0.0, 0.9))
@settings(**_SETTINGS)
def test_async_delay_stream_deterministic_and_bounded(seed, t, m, dist,
                                                      max_delay, drop):
    """Same (seed, round) -> same delays and drops; delays are int32 in
    [0, max_delay]; zero-distribution delays are all zero."""
    from repro.core import strategies as S

    d1, x1 = S.async_delay_stream(seed, t, m, delay_dist=dist,
                                  max_delay=max_delay, dropout_rate=drop)
    d2, x2 = S.async_delay_stream(seed, t, m, delay_dist=dist,
                                  max_delay=max_delay, dropout_rate=drop)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(x1, x2)
    assert d1.shape == (m,) and d1.dtype == np.int32 and x1.shape == (m,)
    assert d1.min() >= 0 and d1.max() <= max(max_delay, 0)
    if dist == "zero":
        assert not d1.any()
    if drop == 0.0:
        assert not x1.any()


@given(seed=st.integers(0, 500), t=st.integers(0, 50))
@settings(**_SETTINGS)
def test_async_stream_disjoint_from_training_and_participation(seed, t):
    """The async key stream never collides with the training key or the
    participation stream for any (seed, round) — enabling async aggregation
    cannot perturb either."""
    from repro.core import strategies as S

    data = lambda k: np.asarray(jax.random.key_data(k))  # noqa: E731
    k_async = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), S._ASYNC_SALT), t)
    k_part = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), 0x9A57), t)
    k_train = jax.random.fold_in(jax.random.key(seed), t)
    assert not np.array_equal(data(k_async), data(k_part))
    assert not np.array_equal(data(k_async), data(k_train))


@given(b=st.integers(1, 4), s=st.sampled_from([16, 32]), seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_causal_forward_prefix_invariance(b, s, seed):
    """Changing suffix tokens never changes prefix logits (dense arch)."""
    from repro import configs
    from repro.models import transformer
    cfg = configs.get_config("qwen3-4b", "smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    key = jax.random.key(seed)
    t1 = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    t2 = t1.at[:, s // 2:].set(
        jax.random.randint(jax.random.fold_in(key, 1), (b, s - s // 2), 0,
                           cfg.vocab_size))
    l1, _ = transformer.forward(params, cfg, t1)
    l2, _ = transformer.forward(params, cfg, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :s // 2]),
                               np.asarray(l2[:, :s // 2]), atol=1e-4, rtol=1e-3)
