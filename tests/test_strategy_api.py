"""The strategy-based engine API: init/step/fit(state=) lifecycle, true
resume through checkpoint round-trips, the method registry, and fixed-seed
history regressions pinning the redesign to the pre-refactor engine."""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint import io
from repro.core import registry
from repro.core import strategies as S
from repro.core.baselines import REGISTRY as BASELINES
from repro.core.fedgl import FGLTrainer
from repro.core.spreadfgl import make_spreadfgl


# The `small` fixture (this exact graph/partition/config) is session-scoped
# in tests/conftest.py and shared across suites.

# Fixed-seed histories of fit(jax.random.key(0), batch, rounds=4) on the
# `small` fixture. Originally captured at the commit before the strategy
# redesign; re-pinned once after the aug-slot link-target bugfix (link
# targets are now restricted to real local slots, so every fixing round
# AFTER the first selects slightly different links — round 0, where no aug
# slot is populated yet, is bit-identical to the pre-fix goldens, which
# also pins that dropping the generator's dead per-iteration key plumbing
# changed nothing).
GOLDEN_SPREADFGL = {
    "loss": [1.4747446775436401, 0.2465604543685913,
             0.06842657178640366, 0.03665665537118912],
    "acc": [0.16363635659217834, 0.23636363446712494,
            0.30909091234207153, 0.3636363744735718],
    "f1": [0.09297052770853043, 0.17866826057434082,
           0.25934067368507385, 0.33452627062797546],
}
GOLDEN_FEDGL = {
    "loss": [1.5929425954818726, 0.27329501509666443,
             0.07562695443630219, 0.03868856653571129],
    "acc": [0.16363635659217834, 0.23636363446712494,
            0.34545454382896423, 0.34545454382896423],
    "f1": [0.09297052770853043, 0.18033909797668457,
           0.2997002899646759, 0.3178369402885437],
}


class TestHistoryRegression:
    """Fixed-seed histories are unchanged across the strategy redesign."""

    @pytest.mark.parametrize("name,kw,golden", [
        ("SpreadFGL", {"num_servers": 2}, GOLDEN_SPREADFGL),
        ("FedGL", {}, GOLDEN_FEDGL),
    ])
    def test_fit_matches_pre_refactor_golden(self, small, name, kw, golden):
        batch, cfg = small
        tr = registry.build(name, cfg, batch, **kw)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=4)
        for k, want in golden.items():
            np.testing.assert_allclose(hist[k], want, atol=1e-4,
                                       err_msg=f"{name} history[{k!r}] drifted")

    def test_step_matches_fit(self, small):
        """Driving step() by hand reproduces fit() exactly."""
        batch, cfg = small
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        _, hist = tr.fit(jax.random.key(0), batch, rounds=3)
        state = tr.init(jax.random.key(0), batch)
        for i in range(3):
            state, m = tr.step(state)
            assert m["round"] == i == hist["round"][i]
            np.testing.assert_array_equal(float(m["loss"]), hist["loss"][i])
            np.testing.assert_array_equal(float(m["acc"]), hist["acc"][i])
        assert state.round == 3

    def test_step_does_not_mutate_input_state(self, small):
        batch, cfg = small
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        state = tr.init(jax.random.key(0), batch)
        before = jax.tree.map(np.asarray, state.params)
        _, _ = tr.step(state)
        assert state.round == 0
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(state.params)):
            np.testing.assert_array_equal(a, np.asarray(b))


class TestResume:
    def test_resume_roundtrip_matches_uninterrupted_fit(self, small):
        """fit 6 == fit 3 + checkpoint save/load + fit(state=restored) 3.

        K=2 here, so the schedule imputes at rounds 0, 2, 4: the resumed run
        only matches if fit(state=...) keys imputation off the *absolute*
        round index (round 4 falls in the second half).
        """
        batch, cfg = small
        cfg = dataclasses.replace(cfg, imputation_interval=2)
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        _, full = tr.fit(jax.random.key(0), batch, rounds=6)

        state, first = tr.fit(jax.random.key(0), batch, rounds=3)
        path = os.path.join(tempfile.mkdtemp(), "resume.npz")
        io.save(path, state)
        restored = io.restore(path, tr.init(jax.random.key(0), batch))
        assert restored.round == 3
        state2, second = tr.fit(state=restored, rounds=3)

        assert first["round"] + second["round"] == full["round"] == list(range(6))
        for k in ("loss", "acc", "f1"):
            np.testing.assert_allclose(first[k] + second[k], full[k], atol=1e-6)
        assert state2.round == 6

    def test_fit_requires_state_or_key_and_batch(self, small):
        batch, cfg = small
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        with pytest.raises(ValueError, match="state="):
            tr.fit(rounds=1)

    def test_fit_rejects_state_plus_key_batch(self, small):
        """Passing both is ambiguous: the state's own key/batch would win."""
        batch, cfg = small
        tr = make_spreadfgl(cfg, batch, num_servers=2)
        state = tr.init(jax.random.key(0), batch)
        with pytest.raises(ValueError, match="resumes"):
            tr.fit(jax.random.key(1), batch, state=state, rounds=1)


class TestRegistry:
    def test_all_methods_registered(self):
        assert set(registry.names()) >= {"FedGL", "SpreadFGL", "local",
                                         "fedavg_fusion", "fedsage_plus"}

    def test_unknown_method_lists_available(self, small):
        batch, cfg = small
        with pytest.raises(KeyError, match="SpreadFGL"):
            registry.build("nope", cfg, batch)

    def test_baselines_are_pure_compositions(self, small):
        """Sec. IV-A baselines: plain FGLTrainer + strategies, no subclasses,
        no overridden engine internals."""
        batch, cfg = small
        expected = {
            "local": (S.IdentityAggregator, S.NoImputation),
            "fedavg_fusion": (S.FedAvgAggregator, S.NoImputation),
            "fedsage_plus": (S.FedAvgAggregator, S.LocalGenImputation),
        }
        for name, build in BASELINES.items():
            tr = build(cfg, batch)
            assert type(tr) is FGLTrainer, name
            agg_t, imp_t = expected[name]
            assert type(tr.aggregator) is agg_t
            assert type(tr.imputation) is imp_t
            assert isinstance(tr.topology, S.StarTopology)

    def test_registry_and_baselines_agree(self, small):
        batch, cfg = small
        for name in ("local", "fedavg_fusion", "fedsage_plus"):
            via_registry = registry.build(name, cfg, batch)
            direct = BASELINES[name](cfg, batch)
            assert type(via_registry.aggregator) is type(direct.aggregator)
            assert type(via_registry.imputation) is type(direct.imputation)


class TestStrategies:
    def test_star_topology_layout(self):
        lay = S.StarTopology().build(6)
        assert lay.num_servers == 1 and lay.clients_per_server == 6
        np.testing.assert_array_equal(lay.server_of_client, np.zeros(6))

    def test_ring_topology_rejects_indivisible(self):
        with pytest.raises(ValueError, match="divide"):
            S.RingTopology(num_servers=4).build(6)

    def test_custom_topology_via_make_spreadfgl(self, small):
        batch, cfg = small
        adj = np.ones((2, 2), dtype=np.float32)
        tr = make_spreadfgl(cfg, batch, num_servers=2, adjacency=adj)
        assert isinstance(tr.topology, S.CustomTopology)
        assert tr.n_servers == 2

    def test_custom_topology_shape_mismatch(self, small):
        batch, cfg = small
        with pytest.raises(ValueError, match="num_servers"):
            make_spreadfgl(cfg, batch, num_servers=4,
                           adjacency=np.ones((2, 2), np.float32))

    def test_custom_topology_rejects_non_square(self):
        with pytest.raises(ValueError, match="square"):
            S.CustomTopology(np.ones((2, 3), np.float32)).build(4)

    def test_custom_topology_rejects_indivisible(self):
        with pytest.raises(ValueError, match="divide"):
            S.CustomTopology(np.ones((3, 3), np.float32)).build(4)

    def test_custom_topology_layout(self):
        adj = np.asarray([[1, 0], [0, 1]], np.float32)
        lay = S.CustomTopology(adj).build(6)
        assert lay.num_servers == 2 and lay.clients_per_server == 3
        np.testing.assert_array_equal(lay.adjacency, adj)
        np.testing.assert_array_equal(lay.server_of_client,
                                      np.repeat(np.arange(2), 3))

    def test_identity_aggregator_ignores_round_and_mask(self):
        """Identity stays identity under every (round, mask) combination —
        the `local` baseline must be untouched by participation or phase."""
        params = {"w": np.arange(12.0).reshape(6, 2)}
        for round_, mask in [(0, None), (1, None),
                             (0, np.asarray([1, 0, 1, 0, 1, 0], np.float32))]:
            out = S.IdentityAggregator().aggregate(
                params, adj=np.eye(2, dtype=np.float32), num_servers=2,
                m_per=3, round=round_, mask=mask)
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          params["w"])

    def test_identity_aggregator_never_mixes(self, small):
        batch, cfg = small
        tr = registry.build("local", cfg, batch)
        state = tr.init(jax.random.key(0), batch)
        perturbed = jax.tree.map(
            lambda p: p + np.arange(p.shape[0], dtype=np.float32).reshape(
                (-1,) + (1,) * (p.ndim - 1)), state.params)
        agg = tr.aggregate(perturbed)
        for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(perturbed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_imputation_is_inert(self, small):
        batch, cfg = small
        tr = registry.build("fedavg_fusion", cfg, batch)
        assert not tr.imputation.active
        state = tr.init(jax.random.key(0), batch)
        assert tr.imputation.impute(tr, state) is state

    def test_metrics_stay_on_device_until_fetched(self, small):
        """step() metrics are jax arrays (no per-round host sync in fit)."""
        batch, cfg = small
        tr = registry.build("fedavg_fusion", cfg, batch)
        state = tr.init(jax.random.key(0), batch)
        _, m = tr.step(state)
        for k in ("loss", "acc", "f1"):
            assert isinstance(m[k], jax.Array), k
        assert isinstance(m["round"], int)
