"""Chunkwise-scan vs recurrent-step equivalence for the SSM mixers.

The chunkwise forms (TPU adaptation) must match the plain per-token
recurrence exactly (same math, different association) — this is the key
correctness property behind long_500k decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm, xlstm

KEY = jax.random.key(7)


@pytest.mark.parametrize("b,s,d,state", [(2, 16, 24, 8), (1, 64, 16, 4),
                                         (3, 128, 8, 16)])
def test_mamba_chunked_equals_stepwise(b, s, d, state):
    p = ssm.init_mamba(KEY, d, expand=2, state=state, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, s), (b, s, d))
    y_par = ssm.apply_mamba(p, x, state=state)
    cache = ssm.init_mamba_state(b, d, expand=2, state=state)
    outs = []
    for t in range(s):
        yt, cache = ssm.decode_mamba(p, x[:, t:t + 1], cache, state=state)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-4)


def test_mamba_final_state_matches():
    b, s, d, state = 2, 32, 12, 8
    p = ssm.init_mamba(KEY, d, expand=2, state=state, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(KEY, (b, s, d))
    _, st_par = ssm.apply_mamba(p, x, state=state, return_state=True)
    cache = ssm.init_mamba_state(b, d, expand=2, state=state)
    for t in range(s):
        _, cache = ssm.decode_mamba(p, x[:, t:t + 1], cache, state=state)
    np.testing.assert_allclose(np.asarray(st_par["h"]), np.asarray(cache["h"]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,s,d,h", [(2, 16, 32, 2), (1, 256, 16, 4),
                                     (2, 100, 24, 3)])
def test_mlstm_chunked_equals_stepwise(b, s, d, h):
    if s % min(xlstm.CHUNK, s) != 0:
        s = (s // 4) * 4
    p = xlstm.init_mlstm(KEY, d, h, expand=2, dtype=jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.fold_in(KEY, s + d), (b, s, d))
    y_par = xlstm.apply_mlstm(p, x, h)
    cache = xlstm.init_mlstm_state(b, d, h, expand=2)
    outs = []
    for t in range(s):
        yt, cache = xlstm.decode_mlstm(p, x[:, t:t + 1], cache, h)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=3e-4, rtol=3e-4)


def test_mlstm_state_carry_across_chunks():
    """Sequences longer than one chunk still match the recurrence."""
    b, s, d, h = 1, 2 * xlstm.CHUNK, 16, 2
    p = xlstm.init_mlstm(KEY, d, h, expand=2, dtype=jnp.float32)
    x = 0.3 * jax.random.normal(KEY, (b, s, d))
    y_par, st = xlstm.apply_mlstm(p, x, h, return_state=True)
    cache = xlstm.init_mlstm_state(b, d, h, expand=2)
    for t in range(s):
        yt, cache = xlstm.decode_mlstm(p, x[:, t:t + 1], cache, h)
    np.testing.assert_allclose(np.asarray(y_par[:, -1]), np.asarray(yt[:, 0]),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(st["c"]), np.asarray(cache["c"]),
                               atol=3e-4, rtol=3e-4)


def test_slstm_scan_equals_stepwise():
    b, s, d = 2, 24, 16
    p = xlstm.init_slstm(KEY, d, 2, dtype=jnp.float32)
    x = 0.5 * jax.random.normal(KEY, (b, s, d))
    y_par, st = xlstm.apply_slstm(p, x, 2, return_state=True)
    cache = xlstm.init_slstm_state(b, d)
    outs = []
    for t in range(s):
        yt, cache = xlstm.decode_slstm(p, x[:, t:t + 1], cache)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(cache["h"]),
                               atol=1e-5)


def test_mamba_causality():
    """Future inputs must not affect past outputs."""
    b, s, d, state = 1, 32, 12, 8
    p = ssm.init_mamba(KEY, d, expand=2, state=state, dtype=jnp.float32)
    x1 = jax.random.normal(KEY, (b, s, d))
    x2 = x1.at[:, 20:].add(10.0)
    y1 = ssm.apply_mamba(p, x1, state=state)
    y2 = ssm.apply_mamba(p, x2, state=state)
    np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]),
                               atol=1e-5)


def test_mlstm_causality():
    b, s, d, h = 1, 64, 16, 2
    p = xlstm.init_mlstm(KEY, d, h, expand=2, dtype=jnp.float32)
    x1 = 0.3 * jax.random.normal(KEY, (b, s, d))
    x2 = x1.at[:, 40:].add(5.0)
    y1 = xlstm.apply_mlstm(p, x1, h)
    y2 = xlstm.apply_mlstm(p, x2, h)
    np.testing.assert_allclose(np.asarray(y1[:, :40]), np.asarray(y2[:, :40]),
                               atol=1e-5)
