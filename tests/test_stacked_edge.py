"""Regression tests for the stacked [N] edge-server layout.

The vmapped imputation round must be numerically equivalent to the seed's
sequential per-server loop (kept as ``_imputation_round_reference``), the
stacked state must contain no Python lists, checkpoints must round-trip, and
the Pallas kernel wrappers must survive non-block-multiple shapes via the
``ops.py`` padding path (the shapes the vmapped round actually feeds them).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io
from repro.core import assessor as assessor_lib
from repro.core import imputation, patcher
from repro.core.partition import partition_graph
from repro.core.spreadfgl import make_spreadfgl
from repro.core.types import FGLConfig
from repro.data.synthetic_graphs import DATASETS, make_sbm_graph
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def setup2(small):
    """Fixed-seed 2-server / 4-client trainer + state on the shared batch."""
    batch, cfg = small
    tr = make_spreadfgl(cfg, batch, num_servers=2)
    state = tr.init(jax.random.key(0), batch)
    return tr, state


class TestStackedEquivalence:
    def test_vmapped_matches_sequential_loop(self, setup2):
        """vmap over the [N] axis == the seed's per-server Python loop."""
        tr, state = setup2
        out_v = tr._impute_fn(state)
        out_s = jax.jit(tr._imputation_round_reference)(state)
        # batch (graph fixing), generator params + opt states all agree.
        for field in ("batch", "ae_params", "ae_opt", "as_params", "as_opt"):
            for a, b in zip(jax.tree.leaves(getattr(out_v, field)),
                            jax.tree.leaves(getattr(out_s, field))):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32), atol=1e-5)

    def test_state_has_no_python_lists(self, setup2):
        _, state = setup2
        for tree in (state.ae_params, state.ae_opt, state.as_params,
                     state.as_opt):
            assert not isinstance(tree, (list, tuple)) or hasattr(tree, "_fields")
            for leaf in jax.tree.leaves(tree):
                assert leaf.shape[0] == 2  # leading [N] axis

    def test_stacked_init_matches_per_server_init(self, setup2):
        """Stacked init is bit-identical to fold_in-per-server seed init."""
        tr, state = setup2
        k_cls, k_ae, k_as, k_run = jax.random.split(jax.random.key(0), 4)
        for j in range(2):
            ae_j = imputation.init_autoencoder(
                jax.random.fold_in(k_ae, j), tr.num_classes, tr.feature_dim,
                tr.cfg.ae_hidden)
            as_j = assessor_lib.init_assessor(
                jax.random.fold_in(k_as, j), tr.num_classes,
                tr.cfg.assessor_hidden)
            for a, b in zip(jax.tree.leaves(ae_j),
                            jax.tree.leaves(jax.tree.map(lambda x: x[j],
                                                         state.ae_params))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(as_j),
                            jax.tree.leaves(jax.tree.map(lambda x: x[j],
                                                         state.as_params))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stitch_server_links_offsets(self):
        n, n_flat, k, d = 3, 4, 2, 5
        scores = jnp.ones((n, n_flat, k))
        idx = jnp.tile(jnp.array([[0, -1]], jnp.int32), (n, n_flat, 1))
        x_bar = jnp.zeros((n, n_flat, d))
        s2, i2, x2 = patcher.stitch_server_links(scores, idx, x_bar)
        assert s2.shape == (n * n_flat, k) and x2.shape == (n * n_flat, d)
        i2 = np.asarray(i2)
        for j in range(n):
            block = i2[j * n_flat:(j + 1) * n_flat]
            assert (block[:, 0] == j * n_flat).all()   # offset applied
            assert (block[:, 1] == -1).all()           # invalid stays -1

    def test_fit_metrics_single_compiled_eval(self, setup2):
        """fit() metrics come from the fused (loss, acc, f1) eval call."""
        tr, state = setup2
        loss, acc, f1 = tr._eval_fn(state.params, state.batch)
        expect = float(tr._client_loss(state.params, state.batch)) / tr.m
        np.testing.assert_allclose(float(loss), expect, rtol=1e-6)
        assert np.isfinite(float(acc)) and np.isfinite(float(f1))


class TestCheckpointStackedState:
    def test_fgl_state_roundtrips(self, setup2):
        tr, state = setup2
        path = os.path.join(tempfile.mkdtemp(), "fgl_state.npz")
        io.save(path, state)
        restored = io.restore(path, state)
        for a, b in zip(jax.tree.leaves(jax.random.key_data(state.key)),
                        jax.tree.leaves(jax.random.key_data(restored.key))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        skip = {id(state.key)}
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            if id(a) in skip:
                continue
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restored_state_continues_training(self, setup2):
        tr, state = setup2
        path = os.path.join(tempfile.mkdtemp(), "fgl_state.npz")
        io.save(path, state)
        restored = io.restore(path, state)
        out = tr._impute_fn(restored)
        for leaf in jax.tree.leaves(out.batch):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()


class TestEdgeMesh:
    def test_make_edge_mesh_divides_servers(self):
        from repro.launch.mesh import make_edge_mesh
        mesh = make_edge_mesh(4)
        assert 4 % mesh.size == 0
        assert mesh.axis_names == ("edge",)

    def test_trainer_with_edge_mesh_runs(self):
        from repro.launch.mesh import make_edge_mesh
        g = make_sbm_graph(DATASETS["cora"], scale=0.08, seed=1)
        batch, _ = partition_graph(g, 4, aug_max=8, seed=0)
        cfg = FGLConfig(hidden_dim=16, local_rounds=2, imputation_interval=1,
                        top_k_links=3, aug_max=8)
        tr = make_spreadfgl(cfg, batch, num_servers=2,
                            edge_mesh=make_edge_mesh(2))
        _, hist = tr.fit(jax.random.key(0), batch, rounds=2)
        assert np.isfinite(hist["loss"]).all()

    def test_indivisible_mesh_rejected(self):
        import types
        g = make_sbm_graph(DATASETS["cora"], scale=0.08, seed=1)
        batch, _ = partition_graph(g, 6, aug_max=8, seed=0)
        cfg = FGLConfig(hidden_dim=16, aug_max=8)
        fake_mesh = types.SimpleNamespace(size=2)  # 3 servers % 2 devices != 0
        with pytest.raises(ValueError, match="divide"):
            make_spreadfgl(cfg, batch, num_servers=3, edge_mesh=fake_mesh)


class TestKernelPaddingPaths:
    """Interpret-mode kernels on shapes that are NOT block multiples."""

    @pytest.mark.parametrize("b,n,c,bm,bn", [(33, 70, 7, 16, 32),
                                             (5, 200, 10, 8, 64),
                                             (96, 96, 6, 128, 512)])
    def test_sim_block_non_multiple(self, b, n, c, bm, bn):
        key = jax.random.key(b + n)
        rows = jax.random.normal(key, (b, c))
        h = jax.random.normal(jax.random.fold_in(key, 1), (n, c))
        out = ops.sim_block(rows, h, block_m=bm, block_n=bn, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref.sim_block(rows, h)),
                                   atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("n,d,bm", [(75, 19, 32), (130, 33, 64), (40, 12, 128)])
    def test_sage_aggregate_non_multiple(self, n, d, bm):
        key = jax.random.key(n + d)
        a = (jax.random.uniform(key, (n, n)) < 0.2).astype(jnp.float32)
        h = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
        out = ops.sage_aggregate(a, h, block_m=bm, block_n=bm, block_k=bm,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.sage_aggregate(a, h)),
                                   atol=1e-5, rtol=1e-5)

    def test_vmapped_similarity_topk_matches_loop(self):
        """similarity_topk under vmap (the [N] axis) == per-server calls."""
        key = jax.random.key(0)
        n_srv, m_per, n_pad, c, k = 2, 2, 16, 5, 3
        h = jax.random.normal(key, (n_srv, m_per * n_pad, c))
        mask = jnp.ones((n_srv, m_per * n_pad))
        cid = imputation.client_of_flat(m_per, n_pad)
        s_v, i_v = jax.vmap(
            lambda hj, mj: imputation.similarity_topk(hj, mj, cid, k, block=8)
        )(h, mask)
        for j in range(n_srv):
            s_j, i_j = imputation.similarity_topk(h[j], mask[j], cid, k, block=8)
            np.testing.assert_allclose(np.asarray(s_v[j]), np.asarray(s_j),
                                       atol=1e-5)
            np.testing.assert_array_equal(np.asarray(i_v[j]), np.asarray(i_j))
